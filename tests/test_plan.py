"""Planner/oracle equivalence: execute_fold must agree with the generic
monoid folds for every zoo monoid across all tiers, and registered kernel
lowerings must preserve the monoid laws (associativity / identity) — the
invariant that licenses the planner to re-bracket and relocate folds."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis, or skip-stub when absent

import repro.optim  # noqa: F401  (registers the lossy compression monoids)
from repro.core import execute_fold, local_fold, monoids, plan_fold
from repro.core.monoid import _KERNEL_LOWERINGS
from repro.core.plan import (_segment_fold_generic, collective_algorithm,
                             segment_fold)

KEYED_LAYOUTS = ("kernel", "segment", "scan")


def _keyed_samples(name, n, d, rng):
    """(monoid, lifted values pytree) for a keyed fold of n records."""
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    if name == "sum":
        return monoids.sum_, vals
    if name == "max":
        return monoids.max_, vals
    if name == "min":
        return monoids.min_, vals
    if name == "count":
        return monoids.count, jnp.ones((n,), jnp.int32)
    if name == "mean":
        return monoids.mean, (vals, jnp.ones((n,), jnp.int32))
    if name == "bitwise_or":
        bits = jnp.asarray(rng.integers(0, 2, size=(n, d)).astype(np.uint8))
        return monoids.bitwise_or, bits
    raise ValueError(name)


def _assert_tree_close(m, got, want, rtol=1e-4, atol=1e-4):
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float64),
                                   np.asarray(w, np.float64),
                                   rtol=rtol, atol=atol, err_msg=m.name)


@settings(max_examples=8, deadline=None)
@given(name=st.sampled_from(["sum", "max", "min", "count", "mean",
                             "bitwise_or"]),
       n=st.integers(5, 120), d=st.integers(1, 9), s=st.integers(2, 10),
       layout=st.sampled_from(KEYED_LAYOUTS))
def test_keyed_tiers_match_generic_oracle(name, n, d, s, layout):
    """Every tier == the generic serial-scan oracle, for every keyed zoo
    monoid (the planner may choose any tier without changing the answer)."""
    rng = np.random.default_rng(n * d + s)
    m, values = _keyed_samples(name, n, d, rng)
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    got = execute_fold(m, values, segment_ids=segs, num_segments=s,
                       layout=layout, block_n=64)
    want = _segment_fold_generic(m, values, segs, s)
    _assert_tree_close(m, got, want)


def _ragged_oracle(m, values, segs, s, mask):
    """Fold over ONLY the valid rows (dense oracle for valid_mask)."""
    keep = np.asarray(mask)
    if not keep.any():
        one = jax.tree_util.tree_map(lambda v: v[0], values)
        ident = m.identity_like(one)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (s,) + l.shape), ident)
    kept = jax.tree_util.tree_map(lambda v: jnp.asarray(np.asarray(v)[keep]),
                                  values)
    return _segment_fold_generic(m, kept, jnp.asarray(np.asarray(segs)[keep]),
                                 s)


@settings(max_examples=8, deadline=None)
@given(name=st.sampled_from(["sum", "max", "min", "count", "mean",
                             "bitwise_or"]),
       n=st.integers(5, 120), d=st.integers(1, 9), s=st.integers(2, 10),
       frac=st.floats(0.0, 1.0),
       layout=st.sampled_from(KEYED_LAYOUTS))
def test_ragged_keyed_fold_matches_dense_over_valid(name, n, d, s, frac,
                                                    layout):
    """The ragged contract on every tier: a keyed fold with valid_mask ==
    the fold over only the valid rows, for the whole keyed zoo — including
    all-False masks (every key holds the identity)."""
    rng = np.random.default_rng(n * d + s + int(frac * 100))
    m, values = _keyed_samples(name, n, d, rng)
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    mask = rng.random(n) < frac
    got = execute_fold(m, values, segment_ids=segs, num_segments=s,
                       layout=layout, valid_mask=jnp.asarray(mask),
                       block_n=64)
    want = _ragged_oracle(m, values, segs, s, mask)
    _assert_tree_close(m, got, want)


@pytest.mark.parametrize("layout", KEYED_LAYOUTS)
def test_ragged_keyed_fold_deterministic(layout):
    """Non-hypothesis coverage of the mask path on all tiers (the skip-stub
    container runs this even without hypothesis installed)."""
    rng = np.random.default_rng(9)
    n, d, s = 53, 4, 6
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    for mask in (rng.random(n) < 0.6, np.zeros(n, bool), np.ones(n, bool)):
        got = execute_fold(monoids.sum_, vals, segment_ids=segs,
                           num_segments=s, layout=layout,
                           valid_mask=jnp.asarray(mask), block_n=16)
        want = _ragged_oracle(monoids.sum_, vals, segs, s, mask)
        _assert_tree_close(monoids.sum_, got, want)


def test_ragged_flat_fold_matches_dense_over_valid():
    """valid_mask on FLAT folds: tree/scan tiers and the fused map_fn scan
    all equal the fold over only the valid rows."""
    rng = np.random.default_rng(21)
    n = 19
    vals = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    mask = rng.random(n) < 0.5
    want = np.asarray(vals)[mask].sum(0)
    for layout in ("tree", "scan"):
        got = execute_fold(monoids.sum_, vals, valid_mask=jnp.asarray(mask),
                           layout=layout)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-5)
    xs = vals[:, 0]
    fused = execute_fold(monoids.mean, xs, map_fn=lambda x: x * 3,
                         valid_mask=jnp.asarray(mask), layout="scan")
    np.testing.assert_allclose(float(monoids.mean.extract(fused)),
                               float(np.asarray(xs)[mask].mean() * 3),
                               rtol=1e-5)


def test_ragged_fold_with_init_and_jit():
    """valid_mask composes with init (the serve loop's running table) and
    with jit (the mask is a tracer — num_valid just falls back to None)."""
    rng = np.random.default_rng(4)
    n, s = 40, 5
    vals = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    mask = jnp.asarray(rng.random(n) < 0.7)
    init = jnp.asarray(rng.normal(size=(s, 2)).astype(np.float32))

    @jax.jit
    def step(t, v, sg, mk):
        return execute_fold(monoids.sum_, v, segment_ids=sg, num_segments=s,
                            valid_mask=mk, init=t)

    got = step(init, vals, segs, mask)
    want = np.asarray(init) + np.asarray(
        _ragged_oracle(monoids.sum_, vals, segs, s, np.asarray(mask)))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_plan_byte_model_counts_only_valid_rows():
    """A concrete mask shows up in the plan: num_valid is static, the local
    tier is marked masked, and Algorithm-1 pair bytes count valid rows."""
    rng = np.random.default_rng(6)
    n = 64
    vals = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 4, n).astype(np.int32))
    mask = np.zeros(n, bool)
    mask[:10] = True
    kw = dict(segment_ids=segs, num_segments=4, mesh_axes=("shard",),
              axis_sizes={"shard": 4})
    p = plan_fold(monoids.sum_, vals, valid_mask=jnp.asarray(mask), **kw)
    assert p.num_valid == 10
    assert "+mask" in p.local_tier.detail
    naive = plan_fold(monoids.sum_, vals, valid_mask=jnp.asarray(mask),
                      pre_combine=False, **kw)
    pair_bytes = 10 * p.value_bytes       # only valid rows become pairs
    assert naive.tiers[0].out_bytes == pair_bytes
    # abstract mask (plan-time ShapeDtypeStruct): count unknown, still masked
    p2 = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=4,
                   valid_mask=jax.ShapeDtypeStruct((n,), jnp.bool_))
    assert p2.num_valid is None and "+mask" in p2.local_tier.detail
    with pytest.raises(ValueError, match="valid_mask"):
        plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=4,
                  valid_mask=jnp.ones((n + 1,), jnp.bool_))


def test_shuffle_stats_count_only_valid_rows():
    """ShuffleStats' byte prediction over a ragged job counts only valid
    records as shuffled pairs (the serve batch's padding is free)."""
    from repro.core import average_by_key_job

    n = 32
    rng = np.random.default_rng(3)
    records = {"key": jnp.asarray(rng.integers(0, 4, n).astype(np.int32)),
               "value": jnp.asarray(rng.normal(size=(n,)).astype(np.float32))}
    mask = np.zeros(n, bool)
    mask[:12] = True
    job = average_by_key_job(num_keys=4)
    dense = job.stats(records, strategy="naive", num_shards=1)
    ragged = job.stats(records, strategy="naive", num_shards=1,
                       valid_mask=jnp.asarray(mask))
    assert dense.shuffle_values == n
    assert ragged.shuffle_values == 12
    assert ragged.shuffle_bytes_mapreduce == 12 * ragged.value_bytes
    assert ragged.num_records == n
    # shape-only planning (abstract mask) keeps the no-FLOPs contract and
    # falls back to counting every row
    abstract = job.stats(records, strategy="naive", num_shards=2,
                         valid_mask=jax.ShapeDtypeStruct((n,), jnp.bool_))
    assert abstract.shuffle_values == n
    p = job.plan(records, strategy="combiner", num_shards=2,
                 valid_mask=jax.ShapeDtypeStruct((n,), jnp.bool_))
    assert p.num_valid is None and "+mask" in p.local_tier.detail


def test_keyed_fold_missing_num_segments_error_is_actionable():
    """The keyed error path names the MISSING kwarg (num_segments), not the
    one that was already passed."""
    vals = jnp.ones((8, 2), jnp.float32)
    segs = jnp.zeros((8,), jnp.int32)
    with pytest.raises(ValueError, match="num_segments="):
        plan_fold(monoids.sum_, vals, segment_ids=segs)
    with pytest.raises(ValueError, match="num_segments="):
        execute_fold(monoids.sum_, vals, segment_ids=segs)


@pytest.mark.parametrize("layout", ["kernel", "segment"])
def test_unkeyed_kernel_layout_error_is_actionable(layout):
    """layout='kernel'/'segment' without segment_ids must say what to pass
    (segment_ids= AND num_segments=) and name the flat-fold alternatives."""
    vals = jnp.ones((8, 2), jnp.float32)
    for fn in (plan_fold, execute_fold):
        with pytest.raises(ValueError) as ei:
            fn(monoids.sum_, vals, layout=layout)
        msg = str(ei.value)
        assert "segment_ids=" in msg and "num_segments=" in msg
        assert "tree" in msg and "scan" in msg


@pytest.mark.parametrize("layout", ["tree", "scan"])
@pytest.mark.parametrize("name", sorted(monoids.REGISTRY))
def test_flat_tiers_match_local_fold(name, layout):
    """Flat execute_fold == local_fold for EVERY registry monoid (incl. the
    non-commutative and pytree-state ones)."""
    m = monoids.REGISTRY[name]
    rng = np.random.default_rng(hash(name) % 2**32)
    n, d = 9, 4
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    if name in ("sum", "prod", "max", "min"):
        values = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    elif name == "bitwise_or":
        values = jnp.asarray(rng.integers(0, 2, size=(n, d)).astype(np.uint8))
    elif name in ("mean", "count", "welford", "logsumexp"):
        values = jax.vmap(m.lift)(x)
    elif name == "attn_state":
        values = (x, jnp.abs(x) + 0.5,
                  jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)))
    elif name == "affine_scan":
        values = (jnp.asarray(rng.uniform(0.5, 1.0, n).astype(np.float32)), x)
    else:
        # everything else (sketches, top-k, the lossy compression states):
        # stack the monoid's own registered law samples and compare under
        # its own equality — requantizing monoids are only associative up
        # to their approx_equal, not elementwise
        provider = monoids.law_samples_for(name)
        if provider is None:
            pytest.skip(f"no sample builder for {name}")
        samples = provider()
        values = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *samples)
        got = execute_fold(m, values, layout=layout)
        want = local_fold(m, values, strategy="tree")
        assert m.equal(got, want), (name, layout, got, want)
        return
    got = execute_fold(m, values, layout=layout)
    want = local_fold(m, values, strategy="tree")
    _assert_tree_close(m, got, want)


@pytest.mark.parametrize("name", sorted(_KERNEL_LOWERINGS))
def test_kernel_lowering_preserves_associativity_and_identity(name):
    """Law check for every registered lowering: re-bracketing the keyed fold
    across an arbitrary split == one fold (associativity), and keys that
    receive no records hold the monoid identity."""
    rng = np.random.default_rng(7)
    n, d, s = 90, 3, 6
    m, values = _keyed_samples(name, n, d, rng)
    # route every record to keys [1, s-1): key 0 and key s-1 stay empty
    segs = jnp.asarray(rng.integers(1, s - 1, n).astype(np.int32))
    lower = _KERNEL_LOWERINGS[name].fn

    full = lower(values, segs, s, block_n=32)
    cut = 41   # deliberately not a block multiple
    head = jax.tree_util.tree_map(lambda v: v[:cut], values)
    tail = jax.tree_util.tree_map(lambda v: v[cut:], values)
    rebracketed = jax.vmap(m.combine)(lower(head, segs[:cut], s, block_n=32),
                                      lower(tail, segs[cut:], s, block_n=32))
    _assert_tree_close(m, rebracketed, full)

    one = jax.tree_util.tree_map(lambda v: v[0], values)
    identity = m.identity_like(one)
    for empty_key in (0, s - 1):
        got = jax.tree_util.tree_map(lambda v: v[empty_key], full)
        _assert_tree_close(m, got, identity)


def test_integer_monoids_round_trip_dtype():
    """Exact integer monoids keep their dtype through the kernel tier."""
    rng = np.random.default_rng(11)
    segs = jnp.asarray(rng.integers(0, 5, 64).astype(np.int32))

    ivals = jnp.asarray(rng.integers(-100, 100, size=(64, 3)).astype(np.int32))
    got = execute_fold(monoids.sum_, ivals, segment_ids=segs, num_segments=5,
                       layout="kernel", block_n=32)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jax.ops.segment_sum(ivals, segs,
                                                        num_segments=5)))

    imax = execute_fold(monoids.max_, ivals, segment_ids=segs, num_segments=5,
                        layout="kernel", block_n=32)
    assert imax.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(imax), np.asarray(jax.ops.segment_max(ivals, segs,
                                                         num_segments=5)))

    counts = execute_fold(monoids.count, jnp.ones((64,), jnp.int32),
                          segment_ids=segs, num_segments=5, layout="kernel",
                          block_n=32)
    assert counts.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.bincount(np.asarray(segs), minlength=5))


def test_empty_int_max_segment_gets_dtype_identity():
    """An empty segment under integer max == iinfo.min (segment_max's own
    convention), not a leaked -inf cast."""
    vals = jnp.asarray([[3], [7]], jnp.int32)
    segs = jnp.asarray([0, 0], jnp.int32)
    out = execute_fold(monoids.max_, vals, segment_ids=segs, num_segments=3,
                       layout="kernel", block_n=32)
    assert int(out[1, 0]) == jnp.iinfo(jnp.int32).min
    assert int(out[0, 0]) == 7


def test_auto_layout_down_tiers_wide_integers(monkeypatch):
    """layout='auto' must keep the f32-accumulator kernel tier away from
    integer inputs whose worst-case per-key total can exceed 2**24 — for
    UNSIGNED dtypes the bound comes from iinfo.max (iinfo.min is 0)."""
    from repro.core import plan as plan_mod

    monkeypatch.setattr(plan_mod.jax, "default_backend", lambda: "tpu")
    segs = jax.ShapeDtypeStruct((128,), jnp.int32)

    for dt in (jnp.uint32, jnp.uint64, jnp.int32):
        vals = jax.ShapeDtypeStruct((128, 4), dt)
        p = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=8)
        assert p.local_tier.kind == "segment_ops", dt

    # narrow unsigned stays exact for small batches ...
    small = jax.ShapeDtypeStruct((128, 4), jnp.uint8)
    p = plan_fold(monoids.sum_, small, segment_ids=segs, num_segments=8)
    assert p.local_tier.kind == "kernel"
    # ... but not once 255 * N can reach 2**24 (~65.8k records on one key)
    big = jax.ShapeDtypeStruct((70_000, 4), jnp.uint8)
    segs_big = jax.ShapeDtypeStruct((70_000,), jnp.int32)
    p = plan_fold(monoids.sum_, big, segment_ids=segs_big, num_segments=8)
    assert p.local_tier.kind == "segment_ops"


def test_segment_fold_onehot_bool_leaves_any_backend(monkeypatch):
    """The pre-planner onehot contract covers dtypes the Pallas kernel tier
    rejects (bool): the wrapper must fall back to the XLA matmul rather than
    raise, even when the backend reports TPU."""
    from repro.core import plan as plan_mod

    vals = jnp.asarray([[True], [False], [True], [True]])
    segs = jnp.asarray([0, 0, 1, 1], jnp.int32)
    want = np.asarray([[1], [2]])
    for backend in (jax.default_backend(), "tpu"):
        monkeypatch.setattr(plan_mod.jax, "default_backend", lambda b=backend: b)
        got = segment_fold(monoids.sum_, vals, segs, 2, impl="onehot")
        assert got.dtype == jnp.bool_
        np.testing.assert_array_equal(np.asarray(got), want.astype(bool))


def test_segment_fold_onehot_keeps_float_dtype():
    """impl='onehot' keeps the pre-planner contract: results come back in the
    input leaf's dtype (bf16 in, bf16 out), on and off TPU."""
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.normal(size=(32, 2)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 4, 32).astype(np.int32))
    for dt in (jnp.float32, jnp.bfloat16):
        got = segment_fold(monoids.sum_, vals.astype(dt), segs, 4,
                           impl="onehot")
        assert got.dtype == dt
    want = jax.ops.segment_sum(vals, segs, num_segments=4)
    got = segment_fold(monoids.sum_, vals, segs, 4, impl="onehot")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_default_interpret_env_override(monkeypatch):
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert ops._default_interpret() is False
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert ops._default_interpret() is True
    monkeypatch.delenv("REPRO_INTERPRET")
    assert ops._default_interpret() == (jax.default_backend() != "tpu")


def test_plan_reports_tiers_and_collective_bytes():
    """plan_fold is a pure cost model: ShapeDtypeStructs in, tier chain and
    predicted wire bytes out — ICI axes reduced before the DCN pod axis."""
    pairs = jax.ShapeDtypeStruct((128, 4), jnp.float32)
    segs = jax.ShapeDtypeStruct((128,), jnp.int32)
    p = plan_fold(monoids.sum_, pairs, segment_ids=segs, num_segments=16,
                  mesh_axes=("pod", "data"),
                  axis_sizes={"data": 8, "pod": 2})
    kinds = [t.kind for t in p.tiers]
    assert kinds[0] in ("kernel", "segment_ops")
    # 16 keys divide both axis sizes: the cost model picks the key-sharded
    # reduce-scatter shuffle on both axes (same wire bytes as the ring,
    # ties prefer distributing the per-key reduce)
    assert kinds[1:] == ["reduce_scatter", "reduce_scatter"]
    assert "ici:data" in p.tiers[1].detail          # fast axis first...
    assert "dcn:pod" in p.tiers[2].detail           # ...slow pod axis last
    table_bytes = 16 * 4 * 4
    assert p.out_bytes == table_bytes
    assert p.tiers[1].wire_bytes == 2 * table_bytes * (8 - 1)   # ring-equal
    assert p.tiers[2].wire_bytes == 2 * table_bytes * (2 - 1)
    assert p.shuffle_algorithm == "reduce_scatter"
    assert p.predicted_us > 0
    # 13 keys don't divide either axis: allreduce is the only candidate
    p13 = plan_fold(monoids.sum_, pairs, segment_ids=segs, num_segments=13,
                    mesh_axes=("pod", "data"),
                    axis_sizes={"data": 8, "pod": 2})
    assert [t.kind for t in p13.tiers][1:] == ["allreduce", "allreduce"]

    # generic monoids can't ring-reduce: the planner predicts gather bytes
    assert collective_algorithm(monoids.sum_) == "ring"
    assert collective_algorithm(monoids.top_k(4)) == "gather"


def test_naive_plan_costs_more_than_combined_plan():
    """Algorithm 1 (pre_combine=False) vs 3/4, straight off the planner."""
    pairs = jax.ShapeDtypeStruct((1024, 1), jnp.float32)
    segs = jax.ShapeDtypeStruct((1024,), jnp.int32)
    kw = dict(segment_ids=segs, num_segments=8, mesh_axes=("shard",),
              axis_sizes={"shard": 8})
    naive = plan_fold(monoids.sum_, pairs, pre_combine=False, **kw)
    combined = plan_fold(monoids.sum_, pairs, **kw)
    assert naive.tiers[0].kind == "gather_pairs"
    assert naive.collective_wire_bytes > combined.collective_wire_bytes


def test_segment_fold_wrapper_back_compat():
    """The pre-planner keyed-fold API still dispatches correctly."""
    rng = np.random.default_rng(2)
    vals = jnp.asarray(rng.normal(size=(40, 2)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 4, 40).astype(np.int32))
    want = jax.ops.segment_sum(vals, segs, num_segments=4)
    for impl in ("auto", "onehot", "scan"):
        got = segment_fold(monoids.sum_, vals, segs, 4, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        segment_fold(monoids.max_, vals, segs, 4, impl="onehot")


def test_execute_fold_keyed_init():
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=(30, 2)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 4, 30).astype(np.int32))
    init = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
    for layout in KEYED_LAYOUTS:
        got = execute_fold(monoids.sum_, vals, segment_ids=segs,
                           num_segments=4, layout=layout, init=init,
                           block_n=32)
        want = init + jax.ops.segment_sum(vals, segs, num_segments=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_in_mapper_map_fn_fuses_lift():
    """map_fn + scan layout == materialize-then-fold (Alg 4 == Alg 3)."""
    xs = jnp.arange(24, dtype=jnp.float32)
    fused = execute_fold(monoids.mean, xs, map_fn=lambda x: x * 2 + 1,
                         layout="scan")
    materialized = execute_fold(
        monoids.mean, jax.vmap(lambda x: monoids.mean.lift(x * 2 + 1))(xs),
        layout="tree")
    np.testing.assert_allclose(float(monoids.mean.extract(fused)),
                               float(monoids.mean.extract(materialized)),
                               rtol=1e-6)
    np.testing.assert_allclose(float(monoids.mean.extract(fused)),
                               float(jnp.mean(xs * 2 + 1)), rtol=1e-6)


def test_mesh_tier_single_device():
    """The collective tier runs inside shard_map (1-device smoke; the
    8-device path is exercised in test_distributed.py)."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    vals = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)

    def body(v):
        return execute_fold(monoids.sum_, v, mesh_axes=("data",))

    out = jax.shard_map(body, mesh=mesh,
                        in_specs=jax.sharding.PartitionSpec("data"),
                        out_specs=jax.sharding.PartitionSpec(),
                        check_vma=False)(vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals.sum(0)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# the calibrated cost model: auto == argmin, shuffle choice, forced errors
# ---------------------------------------------------------------------------

def _winner_calibration(winner_layout):
    """A synthetic table making exactly one layout's tier cheap."""
    from repro.core.calibration import CALIB_VERSION, Calibration, TierCoeff
    from repro.core.plan import _LAYOUT_TIER_KIND

    cheap = TierCoeff(t0_us=0.01, us_per_byte=1e-9, us_per_record=1e-9)
    dear = TierCoeff(t0_us=1e4, us_per_byte=1.0, us_per_record=1.0)
    win = _LAYOUT_TIER_KIND[winner_layout]
    return Calibration(
        version=CALIB_VERSION, backend="test", source="measured",
        tiers={kind: {"*": cheap if kind == win else dear}
               for kind in ("kernel", "segment_ops", "scan", "tree")},
        collectives={"ici": TierCoeff(1.0, 1e-5),
                     "dcn": TierCoeff(10.0, 1e-3)})


@settings(max_examples=24, deadline=None)
@given(name=st.sampled_from(["sum", "max", "min", "count", "mean",
                             "bitwise_or"]),
       winner=st.sampled_from(KEYED_LAYOUTS),
       on_tpu=st.booleans())
def test_auto_is_argmin_of_predicted_cost(name, winner, on_tpu):
    """layout='auto' == argmin over the plan's own candidate_us table for
    every keyed zoo monoid, under ANY injected calibration — backend/dtype
    checks only filter feasibility, the cost model decides the winner."""
    from unittest import mock

    from repro.core import plan as plan_mod
    from repro.core.plan import _LAYOUT_TIER_KIND

    rng = np.random.default_rng(7)
    m, values = _keyed_samples(name, 32, 3, rng)
    segs = jnp.asarray(rng.integers(0, 4, 32).astype(np.int32))
    calib = _winner_calibration(winner)
    backend = "tpu" if on_tpu else "cpu"
    with mock.patch.object(plan_mod.jax, "default_backend",
                           return_value=backend):
        p = plan_fold(m, values, segment_ids=segs, num_segments=4,
                      calibration=calib)
    cand = p.candidate_us
    assert cand, "auto plans must report their candidate table"
    best = min(cand, key=cand.get)
    assert p.local_tier.kind == _LAYOUT_TIER_KIND[best]
    assert p.local_tier.predicted_us == pytest.approx(cand[best])
    # kernel may only ever appear as a candidate on the TPU backend
    if not on_tpu:
        assert "kernel" not in cand


def test_auto_follows_injected_calibration_not_heuristics(monkeypatch):
    """Flip the table and the choice flips: scan-cheap beats segment-ops
    even for a monoid with a native segment primitive."""
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 8, 64).astype(np.int32))
    p_scan = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=8,
                       calibration=_winner_calibration("scan"))
    assert p_scan.local_tier.kind == "scan"
    p_seg = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=8,
                      calibration=_winner_calibration("segment"))
    assert p_seg.local_tier.kind == "segment_ops"


def test_shuffle_choice_reduce_scatter_when_keys_divide():
    """Divisible key count -> the cost model picks the key-sharded
    reduce-scatter shuffle (ties break toward it; for gather-fallback
    monoids it is strictly cheaper). Non-divisible -> allreduce only."""
    vals = jax.ShapeDtypeStruct((128, 4), jnp.float32)
    segs = jax.ShapeDtypeStruct((128,), jnp.int32)
    kw = dict(mesh_axes=("data",), axis_sizes={"data": 8})
    p = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=16, **kw)
    assert p.shuffle_algorithm == "reduce_scatter"
    assert set(p.shuffle_candidate_us) == {"reduce_scatter", "allreduce"}
    p13 = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=13,
                    **kw)
    assert p13.shuffle_algorithm == "allreduce"
    assert set(p13.shuffle_candidate_us) == {"allreduce"}
    # generic (gather-allreduce) monoid: reduce_scatter is strictly cheaper
    lifted = jax.ShapeDtypeStruct((128, 4), jnp.float32)
    topk = plan_fold(monoids.top_k(4), lifted, segment_ids=segs,
                     num_segments=16, layout="scan", **kw)
    assert collective_algorithm(monoids.top_k(4)) == "gather"
    assert topk.shuffle_algorithm == "reduce_scatter"
    c = topk.shuffle_candidate_us
    assert c["reduce_scatter"] < c["allreduce"]


def test_shuffle_trivial_or_unknown_axis_is_allreduce():
    vals = jax.ShapeDtypeStruct((32, 2), jnp.float32)
    segs = jax.ShapeDtypeStruct((32,), jnp.int32)
    p1 = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=8,
                   mesh_axes=("data",), axis_sizes={"data": 1})
    assert p1.shuffle_algorithm == "allreduce"
    assert p1.tiers[1].wire_bytes == 0
    p_unknown = plan_fold(monoids.sum_, vals, segment_ids=segs,
                          num_segments=8, mesh_axes=("data",))
    assert p_unknown.shuffle_algorithm == "allreduce"
    assert "size unknown" in p_unknown.tiers[1].detail


def test_forced_infeasible_layout_errors_name_the_leaf(monkeypatch):
    """A forced layout the inputs cannot take fails at PLAN time with the
    offending leaf dtype in the message, not deep inside lowering."""
    segs = jnp.zeros((8,), jnp.int32)
    # kernel on a complex leaf: the error names the dtype and suggests a way out
    with pytest.raises(ValueError, match="complex64"):
        plan_fold(monoids.sum_, jnp.ones((8,), jnp.complex64),
                  segment_ids=segs, num_segments=2, layout="kernel")
    with pytest.raises(ValueError, match="layout='kernel'"):
        plan_fold(monoids.sum_, jnp.ones((8,), jnp.complex64),
                  segment_ids=segs, num_segments=2, layout="kernel")
    # kernel on a monoid with no registered lowering
    with pytest.raises(ValueError, match="no registered Pallas kernel"):
        plan_fold(monoids.top_k(4), jnp.ones((8, 4), jnp.float32),
                  segment_ids=segs, num_segments=2, layout="kernel")
    # segment on a monoid with no XLA segment primitive
    with pytest.raises(ValueError, match="no XLA segment primitive"):
        plan_fold(monoids.top_k(4), jnp.ones((8, 4), jnp.float32),
                  segment_ids=segs, num_segments=2, layout="segment")
    # a pytree leaf path is named when the offender is nested
    with pytest.raises(ValueError, match="count"):
        plan_fold(monoids.product(s=monoids.sum_, count=monoids.sum_),
                  {"s": jnp.ones((8,), jnp.float32),
                   "count": jnp.ones((8,), jnp.complex64)},
                  segment_ids=segs, num_segments=2, layout="kernel")


def test_describe_prints_predicted_microseconds():
    vals = jnp.ones((64, 4), jnp.float32)
    segs = jnp.zeros((64,), jnp.int32)
    p = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=16,
                  mesh_axes=("data", "pod"),
                  axis_sizes={"data": 8, "pod": 2})
    desc = p.describe()
    assert "us]" in desc
    assert p.predicted_us == pytest.approx(
        sum(t.predicted_us for t in p.tiers))


@pytest.mark.parametrize("name", ["sum", "max", "min", "count", "mean",
                                  "bitwise_or"])
@pytest.mark.parametrize("winner", KEYED_LAYOUTS)
def test_auto_argmin_deterministic_zoo(name, winner, monkeypatch):
    """Non-hypothesis coverage of the argmin contract across the whole keyed
    zoo x every winner table x both backends (runs even without hypothesis
    installed)."""
    from repro.core import plan as plan_mod
    from repro.core.plan import _LAYOUT_TIER_KIND

    rng = np.random.default_rng(11)
    m, values = _keyed_samples(name, 32, 3, rng)
    segs = jnp.asarray(rng.integers(0, 4, 32).astype(np.int32))
    calib = _winner_calibration(winner)
    for backend in ("cpu", "tpu"):
        monkeypatch.setattr(plan_mod.jax, "default_backend",
                            lambda b=backend: b)
        p = plan_fold(m, values, segment_ids=segs, num_segments=4,
                      calibration=calib)
        cand = p.candidate_us
        best = min(cand, key=cand.get)
        assert p.local_tier.kind == _LAYOUT_TIER_KIND[best], (
            name, winner, backend, cand)
        if backend == "cpu":
            assert "kernel" not in cand


# ---------------------------------------------------------------------------
# the async tier and the lossy annotation (planning; execution at mesh scale
# lives in test_distributed.py)
# ---------------------------------------------------------------------------

_ASYNC_SIZES = {"x": 4, "pod": 2}


def _flat_mb_shape(n_mb=4, d=256):
    return jax.ShapeDtypeStruct((n_mb, d), jnp.float32)


def test_forced_async_plan_shape():
    p = plan_fold(monoids.sum_, _flat_mb_shape(), mesh_axes=("x", "pod"),
                  layout="async", axis_sizes=_ASYNC_SIZES)
    assert p.local_tier.kind == "async"
    assert len(p.tiers) == 1                 # the whole plan IS the pipeline
    assert p.overlap_modeled > 0.0
    assert dict(p.plan_candidate_us).keys() == {"sync", "async"}
    assert "overlap modeled" in p.describe()


def test_auto_declines_async_for_pure_grad_fold():
    """Per-microbatch crossings replicate the summed bytes n times and the
    epilogue crossing can never hide — so for a pure grad fold the honest
    model keeps choosing sync, with the async price on the record."""
    p = plan_fold(monoids.sum_, _flat_mb_shape(), mesh_axes=("x", "pod"),
                  layout="auto", axis_sizes=_ASYNC_SIZES)
    assert p.local_tier.kind != "async"
    cand = dict(p.plan_candidate_us)
    assert cand["sync"] <= cand["async"]


def test_async_layout_errors_are_actionable():
    vals = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError, match="mesh_axes"):
        plan_fold(monoids.sum_, vals, layout="async")
    with pytest.raises(ValueError, match="keyed"):
        plan_fold(monoids.sum_, vals, layout="async",
                  mesh_axes=("x",), axis_sizes={"x": 4},
                  segment_ids=jnp.zeros((4,), jnp.int32), num_segments=2)


def test_lossy_plan_prices_compressed_crossing():
    dense = plan_fold(monoids.sum_, _flat_mb_shape(), mesh_axes=("x", "pod"),
                      layout="scan", axis_sizes=_ASYNC_SIZES)
    lossy = plan_fold(monoids.sum_, _flat_mb_shape(), mesh_axes=("x", "pod"),
                      layout="scan", axis_sizes=_ASYNC_SIZES, lossy="topk:0.01")
    assert lossy.lossy == "topk:0.01"
    assert 0 < lossy.lossy_wire_bytes < lossy.dense_wire_bytes
    assert lossy.dense_wire_bytes == dense.dense_wire_bytes
    assert dense.lossy_wire_bytes == dense.dense_wire_bytes   # dense == dense
    assert "lossy" in lossy.describe()
    # only the DCN tier moves compressed bytes; the ICI combine stays dense
    dcn = [t for t in lossy.tiers if t.kind == "allreduce" and
           t.detail.startswith("dcn:")]
    assert dcn and "lossy" in dcn[0].detail


def test_lossy_annotation_errors_are_actionable():
    vals = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError, match="keyed"):
        plan_fold(monoids.sum_, vals, mesh_axes=("x",), axis_sizes={"x": 4},
                  segment_ids=jnp.zeros((4,), jnp.int32), num_segments=2,
                  lossy="topk:0.01")
    with pytest.raises(ValueError, match="additive"):
        plan_fold(monoids.max_, vals, mesh_axes=("x",), axis_sizes={"x": 4},
                  lossy="topk:0.01")


def test_async_and_lossy_execute_single_device():
    """1-device smoke of both execution paths (the real 8-device equality
    checks live in test_distributed.py): the async pipeline and the lossy
    sync crossing both run inside shard_map and return the exact / the
    EF-consistent sum."""
    mesh = jax.make_mesh((1, 1), ("x", "pod"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    vals = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    want = np.asarray(vals.sum(0))
    spec = jax.sharding.PartitionSpec(("x", "pod"))

    def run(fn):
        return jax.shard_map(
            lambda v: fn(v), mesh=mesh, in_specs=spec,
            out_specs=jax.sharding.PartitionSpec(), check_vma=False)(vals)

    out = run(lambda v: execute_fold(monoids.sum_, v,
                                     mesh_axes=("x", "pod"), layout="async"))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)

    def lossy_body(v):
        out, ef = execute_fold(monoids.sum_, v, mesh_axes=("x", "pod"),
                               layout="scan", lossy="topk:0.5")
        return out + ef          # EF invariant: applied + residual == truth

    np.testing.assert_allclose(np.asarray(run(lossy_body)), want, rtol=1e-5)
