"""Data pipeline: determinism, host sharding, resume, prefetch, stream stats,
and the ragged (valid_mask) path for packed sequences."""
import jax.numpy as jnp
import numpy as np

from repro.data import (DataConfig, Prefetcher, SyntheticCorpus, init_stats,
                        make_stream_stats, packed_stats, summarize,
                        update_stats)
from repro.core import monoids


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=64, global_batch=8, seed=42)
    base.update(kw)
    return DataConfig(**base)


def test_batches_deterministic_per_step():
    c1 = SyntheticCorpus(_cfg())
    c2 = SyntheticCorpus(_cfg())
    b1, b2 = c1(5), c2(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = c1(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_host_sharding_disjoint_and_sized():
    full = SyntheticCorpus(_cfg())
    h0 = SyntheticCorpus(_cfg(), host_id=0, num_hosts=4)
    h1 = SyntheticCorpus(_cfg(), host_id=1, num_hosts=4)
    assert h0(0)["tokens"].shape == (2, 64)
    assert not np.array_equal(np.asarray(h0(0)["tokens"]),
                              np.asarray(h1(0)["tokens"]))


def test_labels_are_shifted_tokens():
    b = SyntheticCorpus(_cfg())(0)
    t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])
    assert (l[:, -1] == -1).all()


def test_resume_is_stateless():
    """Restarting at step k yields exactly the batches of an unbroken run."""
    c = SyntheticCorpus(_cfg())
    run1 = [np.asarray(c(i)["tokens"]) for i in range(10)]
    c2 = SyntheticCorpus(_cfg())
    run2 = [np.asarray(c2(i)["tokens"]) for i in range(5, 10)]
    for a, b in zip(run1[5:], run2):
        np.testing.assert_array_equal(a, b)


def test_prefetcher_order_and_close():
    c = SyntheticCorpus(_cfg())
    pf = Prefetcher(c, start_step=3, depth=2, num_steps=8)
    steps = [s for s, _ in pf]
    assert steps == [3, 4, 5, 6, 7]
    pf.close()


def test_stream_stats_monoid():
    m = make_stream_stats()
    state = init_stats(m)
    c = SyntheticCorpus(_cfg())
    toks_all = []
    for i in range(3):
        b = c(i)
        state = update_stats(state, b["tokens"])
        toks_all.append(np.asarray(b["tokens"]).ravel())
    toks_all = np.concatenate(toks_all)
    out = summarize(m, state)
    assert out["tokens"] == toks_all.size
    true_distinct = len(np.unique(toks_all))
    assert abs(out["approx_distinct"] - true_distinct) / true_distinct < 0.25
    # CMS count of the most frequent token is an upper bound on truth
    top = np.bincount(toks_all).argmax()
    est = int(monoids.cms_query(state["cms"], jnp.int32(top)))
    assert est >= int((toks_all == top).sum())


def test_ragged_batches_keep_only_whole_docs():
    """ragged=True: rows end at their last EOS, the tail is padding under
    valid_mask, and loss labels on padding are -1 — no rectangle of real
    tokens is materialized."""
    cfg = _cfg(mean_doc_len=16, ragged=True)
    b = SyntheticCorpus(cfg)(0)
    toks = np.asarray(b["tokens"])
    mask = np.asarray(b["valid_mask"])
    labels = np.asarray(b["labels"])
    assert mask.shape == toks.shape
    assert (toks[~mask] == cfg.pad_id).all()
    next_invalid = np.concatenate(
        [~mask[:, 1:], np.ones((toks.shape[0], 1), bool)], axis=1)
    assert (labels[next_invalid] == -1).all()
    for i in range(toks.shape[0]):
        if mask[i].all():
            continue                          # no EOS: whole row one open doc
        last = np.where(mask[i])[0][-1]
        assert toks[i, last] == cfg.eos_id    # every kept row ends a doc
    # valid positions carry exactly the dense corpus' tokens (determinism)
    dense = np.asarray(SyntheticCorpus(_cfg(mean_doc_len=16))(0)["tokens"])
    np.testing.assert_array_equal(toks[mask], dense[mask])


def test_packed_stats_single_masked_fold_matches_numpy():
    cfg = _cfg(mean_doc_len=16, ragged=True)
    b = SyntheticCorpus(cfg)(0)
    st = packed_stats(b["tokens"], b["valid_mask"], eos_id=cfg.eos_id)
    toks = np.asarray(b["tokens"])
    mask = np.asarray(b["valid_mask"])
    np.testing.assert_array_equal(np.asarray(st["tokens"]), mask.sum(1))
    np.testing.assert_array_equal(
        np.asarray(st["docs"]), ((toks == cfg.eos_id) & mask).sum(1))


def test_stream_stats_masked_equals_dense_over_valid():
    """update_stats(valid_mask=) == update_stats over only the valid tokens,
    bit-for-bit across every sketch component (the mask path is the same
    aggregation, not an approximation)."""
    m = make_stream_stats()
    cfg = _cfg(mean_doc_len=16, ragged=True)
    b = SyntheticCorpus(cfg)(0)
    masked = update_stats(init_stats(m), b["tokens"], b["valid_mask"])
    valid = np.asarray(b["tokens"])[np.asarray(b["valid_mask"])]
    dense = update_stats(init_stats(m), jnp.asarray(valid[None, :]))
    for k in ("cms", "hll", "bloom", "count"):
        np.testing.assert_array_equal(np.asarray(masked[k]),
                                      np.asarray(dense[k]), err_msg=k)


def test_stream_stats_merge_across_hosts():
    """Summingbird property: per-host states combine to the global state."""
    m = make_stream_stats()
    h0 = SyntheticCorpus(_cfg(), host_id=0, num_hosts=2)
    h1 = SyntheticCorpus(_cfg(), host_id=1, num_hosts=2)
    s0 = update_stats(init_stats(m), h0(0)["tokens"])
    s1 = update_stats(init_stats(m), h1(0)["tokens"])
    merged = m.combine(s0, s1)
    both = update_stats(update_stats(init_stats(m), h0(0)["tokens"]),
                        h1(0)["tokens"])
    for a, b in zip(np.asarray(merged["cms"]).ravel(),
                    np.asarray(both["cms"]).ravel()):
        assert a == b
    assert int(merged["count"]) == int(both["count"])
