"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) +
interpret-mode allclose. Each kernel is the paper's combiner on a different
hot spot (DESIGN.md §5)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-stub when absent

from repro.kernels import ops, ref


@settings(max_examples=8, deadline=None)
@given(n=st.integers(10, 400), d=st.integers(1, 40), s=st.integers(2, 24),
       block=st.sampled_from([64, 128, 256]))
def test_segment_fold_sweep(n, d, s, block):
    rng = np.random.default_rng(n * d)
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    got = ops.segment_fold(vals, segs, s, block_n=block)
    want = ref.segment_fold_ref(vals, segs, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_fold_dtypes(dtype):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(200, 16)).astype(np.float32)).astype(dtype)
    segs = jnp.asarray(rng.integers(0, 8, 200).astype(np.int32))
    got = ops.segment_fold(vals, segs, 8, block_n=64)
    want = ref.segment_fold_ref(vals.astype(jnp.float32), segs, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("semiring", ["sum", "max", "min"])
def test_segment_fold_valid_mask_drops_rows(semiring):
    """Ragged kernel contract: valid_mask routes rows to the out-of-range
    segment id, so the fold == the dense fold over only the valid rows, for
    every semiring (and mask=None stays the dense path)."""
    from repro.kernels.segment_fold import segment_fold_pallas

    rng = np.random.default_rng(5)
    n, d, s = 150, 6, 7
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    mask = rng.random(n) < 0.5
    got = segment_fold_pallas(vals, segs, s, semiring=semiring, block_n=32,
                              valid_mask=jnp.asarray(mask))
    kept_v = jnp.asarray(np.asarray(vals)[mask])
    kept_s = jnp.asarray(np.asarray(segs)[mask])
    want = segment_fold_pallas(kept_v, kept_s, s, semiring=semiring,
                               block_n=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_mean_by_key_kernel_is_paper_example():
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=(300, 1)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 8, 300).astype(np.int32))
    got = ops.mean_by_key(vals, segs, 8, block_n=128)
    sums, counts = ref.segment_fold_ref(vals, segs, 8, with_count=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(sums / np.maximum(counts, 1)[:, None]),
        rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(50, 2000), depth=st.integers(1, 5),
       width=st.sampled_from([128, 256, 512]))
def test_cms_kernel_sweep(n, depth, width):
    rng = np.random.default_rng(n)
    toks = jnp.asarray(rng.integers(0, 10000, n).astype(np.int32))
    got = ops.cms_update(toks, depth, width, block_n=256)
    want = ref.cms_update_ref(toks, depth, width)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  np.asarray(want, np.int64))


@settings(max_examples=6, deadline=None)
@given(n=st.integers(20, 600), vocab=st.sampled_from([32, 64, 128]),
       window=st.integers(1, 5))
def test_stripes_kernel_sweep(n, vocab, window):
    rng = np.random.default_rng(n + vocab)
    toks = jnp.asarray(rng.integers(0, vocab, n).astype(np.int32))
    got = ops.stripes(toks, vocab, window, block_n=128)
    want = ref.stripes_ref(toks, vocab, window)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  np.asarray(want, np.int64))


@pytest.mark.parametrize("B,H,KV,S,d,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),     # MHA
    (2, 4, 2, 128, 64, 128, 64),    # GQA 2:1
    (1, 8, 2, 256, 64, 64, 128),    # GQA 4:1, rectangular blocks
])
def test_flash_attention_causal(B, H, KV, S, d, bq, bk):
    rng = np.random.default_rng(B * H + S)
    q = jnp.asarray(rng.normal(size=(B, H, S, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, KV, S, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, KV, S, d)).astype(np.float32))
    got = ops.flash_attn(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_noncausal_and_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32)).astype(jnp.bfloat16)
    got = ops.flash_attn(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_attention_matches_attn_state_monoid():
    """The kernel's in-VMEM fold == the monoid fold in repro.core (the same
    algebra at two layers of the stack)."""
    from repro.core import monoids
    rng = np.random.default_rng(9)
    S, d = 64, 16
    q = jnp.asarray(rng.normal(size=(1, 1, S, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, S, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, S, d)).astype(np.float32))
    got = ops.flash_attn(q, k, v, causal=False, block_q=32, block_k=32)
    # monoid fold over two KV chunks
    m = monoids.attn_state
    scale = 1.0 / np.sqrt(d)

    def state(sl):
        s = (q[0, 0] @ k[0, 0, sl].T) * scale       # (S, chunk)
        mx = s.max(-1)
        e = jnp.exp(s - mx[:, None])
        return (mx, e.sum(-1), e @ v[0, 0, sl])

    acc = m.combine(state(slice(0, 32)), state(slice(32, 64)))
    np.testing.assert_allclose(np.asarray(got[0, 0]),
                               np.asarray(m.extract(acc)), rtol=1e-4, atol=1e-4)
