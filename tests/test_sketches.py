"""The paper §3 sketch monoids: CMS, HyperLogLog, Bloom."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-stub when absent

from repro.core import monoids


def test_cms_overestimates_never_under():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, 5000)
    m = monoids.count_min(4, 512)
    sk = monoids.cms_update_batch(m.identity(), jnp.asarray(toks))
    true = np.bincount(toks, minlength=1000)
    for t in rng.choice(1000, 50):
        est = int(monoids.cms_query(sk, jnp.int32(t)))
        assert est >= true[t]
        assert est <= true[t] + 2 * 5000 / 512 * 4     # loose CMS bound


def test_cms_merge_is_sum_of_streams():
    """Monoid property: sketch(A ++ B) == sketch(A) + sketch(B)."""
    rng = np.random.default_rng(1)
    a = rng.integers(0, 100, 300)
    b = rng.integers(0, 100, 400)
    m = monoids.count_min(4, 256)
    sa = monoids.cms_update_batch(m.identity(), jnp.asarray(a))
    sb = monoids.cms_update_batch(m.identity(), jnp.asarray(b))
    sab = monoids.cms_update_batch(m.identity(), jnp.asarray(np.concatenate([a, b])))
    np.testing.assert_array_equal(np.asarray(m.combine(sa, sb)), np.asarray(sab))


@pytest.mark.parametrize("true_n", [100, 1000, 5000])
def test_hll_accuracy(true_n):
    rng = np.random.default_rng(2)
    ids = rng.choice(10_000_000, true_n, replace=False)
    m = monoids.hyperloglog(10)
    regs = monoids.hll_update_batch(m.identity(), jnp.asarray(ids))
    est = float(m.extract(regs))
    # 1024 registers -> ~3.25% std error; allow 5 sigma
    assert abs(est - true_n) / true_n < 0.20, (est, true_n)


def test_hll_merge_is_union():
    rng = np.random.default_rng(3)
    a = rng.choice(100000, 500, replace=False)
    b = rng.choice(100000, 500, replace=False)
    m = monoids.hyperloglog(10)
    ra = monoids.hll_update_batch(m.identity(), jnp.asarray(a))
    rb = monoids.hll_update_batch(m.identity(), jnp.asarray(b))
    rab = monoids.hll_update_batch(m.identity(), jnp.asarray(np.concatenate([a, b])))
    np.testing.assert_array_equal(np.asarray(m.combine(ra, rb)), np.asarray(rab))


def test_bloom_no_false_negatives():
    rng = np.random.default_rng(4)
    present = rng.choice(100000, 200, replace=False)
    m = monoids.bloom_filter(1 << 12)
    filt = m.identity()
    for x in present:
        filt = m.combine(filt, m.lift(jnp.int32(x)))
    for x in present:
        assert bool(monoids.bloom_contains(filt, jnp.int32(x)))
    # false-positive rate sane
    absent = rng.choice(np.setdiff1d(np.arange(200000), present), 200)
    fp = sum(bool(monoids.bloom_contains(filt, jnp.int32(x))) for x in absent)
    assert fp < 40


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 1 << 30), min_size=2, max_size=6))
def test_sketch_monoid_laws(items):
    for mk in (lambda: monoids.count_min(2, 64),
               lambda: monoids.hyperloglog(6),
               lambda: monoids.bloom_filter(256)):
        m = mk()
        samples = [m.lift(jnp.int32(i)) for i in items[:3]]
        from repro.core import check_laws
        check_laws(m, samples)
