"""Per-arch smoke tests (required deliverable f): every assigned architecture
instantiates a REDUCED config and runs one forward/train step + one decode
step on CPU, asserting output shapes and no NaNs. The FULL configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, context_spec, get_config, valid_cells, SHAPES, input_specs
from repro.models import decode_step, init_cache, init_params, loss_fn
from repro.optim import OptConfig, adamw_update, init_opt_state

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = init_params(cfg, KEY)
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda *_: 0, params))
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    ctx_spec = context_spec(cfg, B)
    if ctx_spec is not None:
        batch["context"] = jax.random.normal(
            KEY, (B,) + ctx_spec.shape[1:], cfg.dtype)

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b), has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["tokens"]) == B * S
    opt = init_opt_state(params)
    new_params, opt, om = adamw_update(grads, opt, OptConfig())
    assert np.isfinite(float(om["grad_norm"]))
    for leaf, new in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(new_params)):
        assert leaf.shape == new.shape and leaf.dtype == new.dtype


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = init_params(cfg, KEY)
    B, S = 2, 24
    ctx_spec = context_spec(cfg, B)
    context = None if ctx_spec is None else jax.random.normal(
        KEY, (B,) + ctx_spec.shape[1:], cfg.dtype)
    cache = init_cache(params, cfg, B, S, context=context)
    toks = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t))(params, cache, toks)
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), arch
    assert int(new_cache["pos"]) == 1


def test_cell_accounting():
    """40 assigned cells = 32 runnable + 8 recorded long_500k skips."""
    runnable = sum(len(valid_cells(get_config(a))) for a in ARCH_IDS)
    assert runnable == 32
    skips = sum(1 for a in ARCH_IDS
                if "long_500k" not in valid_cells(get_config(a)))
    assert skips == 8
    assert len(ARCH_IDS) * len(SHAPES) == 40


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_shapes(arch):
    cfg = get_config(arch)
    for cell in valid_cells(cfg):
        specs = input_specs(cfg, SHAPES[cell])
        assert specs["tokens"].dtype == jnp.int32
        if SHAPES[cell].kind == "train":
            assert specs["tokens"].shape == (SHAPES[cell].global_batch,
                                             SHAPES[cell].seq_len)
        if cfg.family in ("audio", "vlm"):
            assert "context" in specs
