"""Model-substrate correctness: decode==forward, chunked==dense, MLA
absorption, GQA, sliding windows."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (ModelConfig, ParamBuilder, RunCtx, decode_step,
                          forward, init_cache, init_params, loss_fn, unembed)
from repro.models import attention as A

KEY = jax.random.PRNGKey(1)


def _f32(name):
    return dataclasses.replace(get_config(name, smoke=True), dtype=jnp.float32)


def _decode_diff(cfg, n=10, ctx_shape=None):
    params, _ = init_params(cfg, KEY)
    B = 2
    toks = jax.random.randint(KEY, (B, n), 0, cfg.vocab_size)
    context = None
    if ctx_shape is not None:
        context = jax.random.normal(KEY, (B,) + ctx_shape, cfg.dtype)
    h, _ = forward(params, cfg, toks, context=context)
    full = unembed(params, cfg, h)
    cache = init_cache(params, cfg, B, n + 4, context=context)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    outs = []
    for i in range(n):
        lg, cache = step(params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    return float(jnp.max(jnp.abs(full - dec)))


@pytest.mark.parametrize("arch", [
    "qwen3-0.6b", "gemma3-1b", "qwen2.5-14b", "starcoder2-15b",
    "deepseek-v2-236b", "qwen2-moe-a2.7b", "jamba-v0.1-52b", "xlstm-1.3b",
])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits.

    This exercises every cache type (KV, MLA latent, mamba (h, conv),
    mLSTM (C, n), sLSTM (h, c)) and the absorbed-MLA equivalence."""
    assert _decode_diff(_f32(arch)) < 2e-2


def test_decode_matches_forward_whisper():
    cfg = _f32("whisper-small")
    assert _decode_diff(cfg, ctx_shape=(cfg.encoder_seq, cfg.d_model)) < 2e-2


def test_decode_matches_forward_vlm():
    cfg = _f32("llama-3.2-vision-90b")
    assert _decode_diff(cfg, ctx_shape=(cfg.num_image_tokens, cfg.d_model)) < 2e-2


def test_chunked_attention_equals_dense():
    """The AttnState-monoid chunked form is a re-bracketing of softmax."""
    cfg = _f32("qwen3-0.6b")
    params, _ = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    h1, _ = forward(params, cfg, toks, ctx=RunCtx())
    h2, _ = forward(params, cfg, toks, ctx=RunCtx(attn_chunk=8))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-3, atol=2e-3)


def test_chunked_ce_equals_dense():
    cfg = _f32("qwen3-0.6b")
    params, _ = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, m1 = loss_fn(params, cfg, batch, RunCtx())
    l2, m2 = loss_fn(params, cfg, batch, RunCtx(ce_chunk=8))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    np.testing.assert_allclose(float(m1["correct"]), float(m2["correct"]))


def test_mla_absorbed_decode_equals_train_form():
    cfg = dataclasses.replace(_f32("deepseek-v2-236b"))
    pb = ParamBuilder(KEY, jnp.float32)
    A.init_mla(pb, cfg)
    p = pb.params
    B, S = 2, 8
    x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.3
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    full = A.mla_attention(p, cfg, x, positions)
    cache = (jnp.zeros((B, S, cfg.kv_lora_rank), jnp.float32),
             jnp.zeros((B, S, cfg.qk_rope_dim), jnp.float32))
    outs = []
    for i in range(S):
        o, cache = A.mla_decode(p, cfg, x[:, i:i + 1], cache, i)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=1e-3, atol=1e-4)


def test_sliding_window_masks_old_tokens():
    """A 'local' layer must ignore keys beyond the window."""
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64, sliding_window=4,
                      layer_pattern=("local",), ffn_pattern=("dense",),
                      dtype=jnp.float32, tie_embeddings=True)
    params, _ = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, 64)
    h1, _ = forward(params, cfg, toks)
    # perturb a token far outside any window of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % 64)
    h2, _ = forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(h1[0, -1]), np.asarray(h2[0, -1]),
                               rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(h1[0, 2]), np.asarray(h2[0, 2]))


def test_moe_local_load_and_dropless():
    from repro.models import moe as M
    cfg = _f32("qwen2-moe-a2.7b")
    params, _ = init_params(cfg, KEY)
    layer = jax.tree_util.tree_map(lambda p: p[0], params["layers"])
    ffn = layer["slot_0"]["ffn"]
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    out, stats = M.moe_ffn_local(ffn, cfg, x)
    assert out.shape == x.shape
    assert int(stats["expert_load"].sum()) == 2 * 16 * cfg.moe_top_k
    # padded experts never routed
    assert int(stats["expert_load"][-cfg.num_padded_experts:].sum()) == 0


def test_grad_flows_through_every_family():
    for arch in ("jamba-v0.1-52b", "xlstm-1.3b", "deepseek-v2-236b"):
        cfg = _f32(arch)
        params, _ = init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        g = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
        norms = [float(jnp.sum(jnp.square(x))) for x in jax.tree_util.tree_leaves(g)]
        assert all(np.isfinite(n) for n in norms), arch
        assert sum(norms) > 0, arch
