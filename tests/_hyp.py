"""hypothesis, or a skip-stub when it isn't installed.

The property-sweep tests (monoid laws, kernels, sketches) use hypothesis;
the pinned toolchain image doesn't ship it (CI installs it via the
``test`` extra).  When absent, every ``@given`` test becomes an explicit
skip instead of a collection error, and strategy construction at module
import time is absorbed by inert stand-ins.
"""
import pytest

try:
    from hypothesis import given, settings  # noqa: F401  (re-exported)
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert strategy: composable/callable so module-level strategy
        expressions (st.lists(st.floats(...)), composite calls) still build."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    _INERT = _Strategy()

    class _Strategies:
        def __getattr__(self, name):
            if name == "composite":
                return lambda f: (lambda *a, **k: _INERT)
            return lambda *a, **k: _INERT

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper
        return deco
