"""End-to-end behaviour: training converges, restart is exact, streaming ==
batch (the Summingbird property, paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import monoids, tree_fold
from repro.launch.train import TrainerConfig, train
from repro.runtime import PreemptionHandler


@pytest.fixture(scope="module")
def short_run(tmp_path_factory):
    tc = TrainerConfig(arch="qwen3-0.6b", steps=16, global_batch=4,
                       seq_len=64, ckpt_dir=str(tmp_path_factory.mktemp("ck")),
                       ckpt_every=8, log_every=4)
    return tc, train(tc)


def test_training_reduces_loss(short_run):
    _, out = short_run
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["loss"])


def test_metrics_accumulator_is_sum_of_steps(short_run):
    tc, out = short_run
    acc = out["metrics_acc"]
    # the last position of every sequence has label -1 (masked)
    assert float(acc["tokens"]) == tc.steps * tc.global_batch * (tc.seq_len - 1)


def test_restart_continues_exactly(short_run, tmp_path):
    """Run 16 steps; separately run 8, 'crash', restore, run 8 more: the
    final params agree (same data by stateless pipeline, same state by
    checkpoint, same aggregate by monoid merge)."""
    tc_full, out_full = short_run
    tc = TrainerConfig(**{**tc_full.__dict__, "ckpt_dir": str(tmp_path),
                          "steps": 8})
    train(tc)                                     # first half, checkpoints at 8
    tc2 = TrainerConfig(**{**tc_full.__dict__, "ckpt_dir": str(tmp_path),
                           "steps": 16})
    out2 = train(tc2)                             # restores at 8, runs 8 more
    for a, b in zip(jax.tree_util.tree_leaves(out_full["params"]),
                    jax.tree_util.tree_leaves(out2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(float(out_full["metrics_acc"]["tokens"]),
                               float(out2["metrics_acc"]["tokens"]))


def test_preemption_checkpoints_and_stops(tmp_path):
    tc = TrainerConfig(arch="qwen3-0.6b", steps=50, global_batch=4,
                       seq_len=64, ckpt_dir=str(tmp_path), ckpt_every=1000)
    h = PreemptionHandler(signals=())
    h.trigger()
    out = train(tc, preemption=h)
    assert out["steps_done"] < 50
    from repro.checkpoint import CheckpointStore
    assert CheckpointStore(str(tmp_path)).latest_step() == out["steps_done"]


def test_streaming_equals_batch_summingbird():
    """Paper §4: the same monoid gives identical answers via a streaming
    fold (one value at a time) and a batch tree-reduction."""
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    m = monoids.welford
    lifted = jax.vmap(m.lift)(xs)
    stream = m.identity_like(jax.tree_util.tree_map(lambda l: l[0], lifted))
    for i in range(64):
        stream = m.combine(stream, jax.tree_util.tree_map(lambda l: l[i], lifted))
    batch = tree_fold(m, lifted)
    s, b = m.extract(stream), m.extract(batch)
    np.testing.assert_allclose(float(s["mean"]), float(b["mean"]), rtol=1e-5)
    np.testing.assert_allclose(float(s["var"]), float(b["var"]), rtol=1e-4)
    np.testing.assert_allclose(float(s["mean"]), xs.mean(), rtol=1e-5)


def test_microbatched_train_step_matches_full():
    """Grad accumulation (in-mapper combining) == one big batch."""
    import dataclasses
    from repro.configs import get_config, ShapeCell
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim import init_opt_state
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype=jnp.float32)
    mesh = make_host_mesh()
    shape = ShapeCell("t", "train", 32, 4)
    toks = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    res = {}
    for name, mb in (("full", 1), ("micro", 4)):
        built = make_train_step(cfg, mesh, shape, num_microbatches=mb,
                                donate=False)
        params, _ = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        _, _, metrics = built.fn(params, opt, batch)
        res[name] = {k: float(v) for k, v in metrics.items()}
    assert abs(res["full"]["loss"] - res["micro"]["loss"]) < 5e-3
    assert res["full"]["tokens"] == res["micro"]["tokens"]
