"""Checkpoint store: atomicity, async, GC, restart exactness (monoid merge)."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.core import monoids


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8), jnp.float32),
            "b16": jax.random.normal(k, (3,), jnp.float32).astype(jnp.bfloat16),
            "step": jnp.int32(7),
            "nested": {"m": jnp.ones((2, 2), jnp.float32)}}


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(3, t)
    step, r = store.restore(t)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(r)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save_async(1, _tree(1))
    store.save_async(2, _tree(2))
    store.wait()
    assert store.latest_step() == 2
    step, r = store.restore(_tree())
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(_tree(2)["w"]))


def test_gc_keeps_newest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(s))
    assert store.all_steps() == [3, 4]


def test_no_tmp_dirs_left(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_aggregate_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    agg = monoids.mean.lift(jnp.float32(4.0))
    store.save(5, _tree(), aggregates={"metrics": ("mean", agg)})
    r = store.restore_aggregate("metrics", like=agg)
    np.testing.assert_allclose(np.asarray(r[0]), 4.0)
    assert int(r[1]) == 1


def test_restart_exactness_monoid_merge(tmp_path):
    """THE paper-driven fault-tolerance property: aggregate(0..n) ==
    combine(aggregate(0..k) from the checkpoint, aggregate(k..n) after
    restart). Exact because the metric accumulator is a Sum monoid."""
    m = monoids.sum_
    stream = [jnp.float32(x) for x in np.random.default_rng(0).normal(size=20)]
    # uninterrupted run
    full = stream[0]
    for x in stream[1:]:
        full = m.combine(full, x)
    # interrupted at k=8: checkpoint, "crash", restore, continue
    store = CheckpointStore(str(tmp_path))
    acc = stream[0]
    for x in stream[1:8]:
        acc = m.combine(acc, x)
    store.save(8, {"dummy": jnp.zeros(())}, aggregates={"acc": ("sum", acc)})
    acc2 = store.restore_aggregate("acc", like=acc)
    for x in stream[8:]:
        acc2 = m.combine(acc2, x)
    np.testing.assert_allclose(float(acc2), float(full), rtol=1e-6)


def test_restore_onto_different_sharding(tmp_path):
    """Elastic-remesh path: the on-disk layout is mesh-agnostic."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(1, t)
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t)
    step, r = store.restore(t, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
