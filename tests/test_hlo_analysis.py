"""The roofline engine itself is load-bearing — regression-test it.

Key invariant: trip-count-scaled analysis of a lax.scan program must match
the analysis of its unrolled twin (XLA's own cost_analysis fails this by
~num_iterations, which is why hlo_analysis exists).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


@pytest.fixture(scope="module")
def scan_vs_unroll():
    n, d = 8, 128
    w = jnp.zeros((n, d, d))
    x = jnp.zeros((4, d))

    def f_scan(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def f_unroll(w, x):
        for i in range(n):
            x = jnp.tanh(x @ w[i])
        return x

    return (analyze_hlo(_compile_text(f_scan, w, x)),
            analyze_hlo(_compile_text(f_unroll, w, x)),
            n, d)


def test_trip_count_detected(scan_vs_unroll):
    scan_cost, _, n, _ = scan_vs_unroll
    assert any(trip == n for _, trip in scan_cost.loops), scan_cost.loops


def test_scan_flops_match_unrolled(scan_vs_unroll):
    scan_cost, unroll_cost, n, d = scan_vs_unroll
    analytic = n * 2 * 4 * d * d
    assert scan_cost.flops == pytest.approx(analytic, rel=0.01)
    assert unroll_cost.flops == pytest.approx(analytic, rel=0.01)


def test_scan_memory_within_2x_of_unrolled(scan_vs_unroll):
    """The fused single-pass model won't be bit-identical across the two
    lowerings (different fusion choices), but must agree to ~2x."""
    scan_cost, unroll_cost, *_ = scan_vs_unroll
    ratio = scan_cost.mem_bytes / max(unroll_cost.mem_bytes, 1)
    assert 0.5 < ratio < 2.0, ratio


def test_collective_bytes_counted():
    mesh = jax.make_mesh((1,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))

    def f(x):
        return jax.lax.psum(x, "d")

    x = jnp.zeros((256,))
    spec = jax.sharding.PartitionSpec("d")
    fn = jax.shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec,
                       check_vma=False)
    text = jax.jit(fn).lower(x).compile().as_text()
    cost = analyze_hlo(text)
    # single-device mesh: the collective may be elided; just assert no crash
    assert cost.flops >= 0


def test_dus_counted_in_place():
    """A scan that dus-updates a big buffer must charge slice bytes per
    step, not the whole buffer."""
    buf = jnp.zeros((64, 1024))
    upd = jnp.ones((1, 1024))

    def f(buf, upd):
        def body(b, i):
            return jax.lax.dynamic_update_slice(b, upd, (i, 0)), None
        return jax.lax.scan(body, buf, jnp.arange(64))[0]

    cost = analyze_hlo(_compile_text(f, buf, upd))
    whole_buffer_64x = 64 * 64 * 1024 * 4
    assert cost.mem_bytes < whole_buffer_64x, (
        f"dus charged {cost.mem_bytes} — whole-buffer accounting regression")


def test_roofline_terms_and_fraction():
    from repro.launch.hlo_analysis import HloCost
    cost = HloCost(flops=197e12, mem_bytes=819e9 / 2, coll_bytes=0.0,
                   coll_by_kind={}, loops=[], raw_cost_analysis={})
    rf = roofline_terms(cost, model_flops_per_chip=197e12 / 2)
    assert rf.dominant == "compute"
    assert rf.bound_s == pytest.approx(1.0)
    assert rf.roofline_fraction() == pytest.approx(0.5)
    assert rf.useful_flops_ratio() == pytest.approx(0.5)


def test_score_bytes_substitution():
    """S^2-shaped tensors are tracked so the flash-kernel substitution can
    remove them."""
    S = 64
    q = jnp.zeros((2, S, 32))
    k = jnp.zeros((2, S, 32))
    v = jnp.zeros((2, S, 32))

    def attn(q, k, v):
        s = jnp.einsum("bqd,bkd->bqk", q, k)
        return jax.nn.softmax(s, -1) @ v

    cost = analyze_hlo(_compile_text(attn, q, k, v), seq_len=S)
    assert cost.score_bytes > 0
    assert cost.flash_substituted_mem() < cost.mem_bytes
