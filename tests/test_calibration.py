"""The calibrated cost model: cache round-trip, stale-version invalidation,
the coefficient fallback chain, and the microbenchmark fitting math."""
import json

import pytest

import jax
import jax.numpy as jnp

from repro.core import monoids, plan_fold
from repro.core.calibration import (CALIB_VERSION, Calibration, TierCoeff,
                                    calibration_path, default_calibration,
                                    fit_link_coeff, fit_tier_coeff,
                                    get_calibration, load_calibration,
                                    save_calibration, use_calibration)


def _synthetic(scan_cheap=False):
    """A table that inverts the default ordering when scan_cheap is set."""
    fast = TierCoeff(t0_us=0.1, us_per_byte=1e-7, us_per_record=1e-6)
    slow = TierCoeff(t0_us=50.0, us_per_byte=1e-2, us_per_record=1.0)
    return Calibration(
        version=CALIB_VERSION, backend="test", source="measured",
        tiers={"kernel": {"*": slow},
               "segment_ops": {"*": fast if not scan_cheap else slow},
               "scan": {"*": slow if not scan_cheap else fast},
               "tree": {"*": fast}},
        collectives={"ici": TierCoeff(5.0, 1e-4),
                     "dcn": TierCoeff(50.0, 1e-3)})


# -- cache round-trip --------------------------------------------------------

def test_cache_round_trip_identical_plans(tmp_path):
    """write -> load -> the loaded table drives plan_fold to the SAME tier
    choices and predicted times as the in-memory original."""
    calib = _synthetic(scan_cheap=True)
    path = save_calibration(calib, str(tmp_path / "calib.json"))
    loaded = load_calibration(path)
    assert loaded is not None
    assert loaded.to_json() == calib.to_json()

    vals = jnp.ones((64, 4), jnp.float32)
    segs = jnp.zeros((64,), jnp.int32)
    kw = dict(segment_ids=segs, num_segments=16, mesh_axes=("data",),
              axis_sizes={"data": 8})
    p1 = plan_fold(monoids.sum_, vals, calibration=calib, **kw)
    p2 = plan_fold(monoids.sum_, vals, calibration=loaded, **kw)
    assert [t.kind for t in p1.tiers] == [t.kind for t in p2.tiers]
    assert p1.predicted_us == pytest.approx(p2.predicted_us)
    assert p1.candidate_us == p2.candidate_us
    # the synthetic table made scan cheaper than segment-ops: the planner
    # must follow the table, not the default heuristic ordering
    assert p1.local_tier.kind == "scan"


def test_get_calibration_resolves_disk_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "calib.json")
    save_calibration(_synthetic(), path)
    monkeypatch.setenv("REPRO_CALIB", path)
    active = get_calibration()
    assert active.source == "measured"
    assert active.backend == "test"


# -- stale-version invalidation ---------------------------------------------

def test_stale_version_is_invalidated(tmp_path, monkeypatch):
    """A table written under any other schema version is treated exactly
    like no table: load returns None, the planner gets the shipped default."""
    path = str(tmp_path / "calib.json")
    payload = _synthetic().to_json()
    payload["version"] = CALIB_VERSION + 1
    with open(path, "w") as f:
        json.dump(payload, f)
    assert load_calibration(path) is None
    monkeypatch.setenv("REPRO_CALIB", path)
    assert get_calibration().source == "default"


def test_corrupt_cache_is_invalidated(tmp_path):
    path = tmp_path / "calib.json"
    path.write_text("{not json")
    assert load_calibration(str(path)) is None
    assert load_calibration(str(tmp_path / "missing.json")) is None


def test_env_sentinels_disable_disk(monkeypatch):
    for sentinel in ("none", "off", "default", ""):
        monkeypatch.setenv("REPRO_CALIB", sentinel)
        assert calibration_path() is None
        assert get_calibration().source == "default"
    monkeypatch.setenv("REPRO_CALIB", "none")
    with pytest.raises(ValueError):
        save_calibration(_synthetic())


def test_use_calibration_scoped_override():
    calib = _synthetic()
    with use_calibration(calib) as active:
        assert get_calibration() is calib is active
    assert get_calibration() is not calib


# -- the coefficient fallback chain -----------------------------------------

def test_tier_coeff_fallback_chain():
    specific = TierCoeff(1.0, 1.0, 1.0)
    by_monoid = TierCoeff(2.0, 2.0, 2.0)
    by_dtype = TierCoeff(3.0, 3.0, 3.0)
    generic = TierCoeff(4.0, 4.0, 4.0)
    calib = Calibration(
        version=CALIB_VERSION, backend="t", source="measured",
        tiers={"scan": {"sum|float32": specific, "sum|*": by_monoid,
                        "*|float32": by_dtype, "*": generic}},
        collectives={})
    assert calib.tier_coeff("scan", "sum", "float32") is specific
    assert calib.tier_coeff("scan", "sum", "int32") is by_monoid
    assert calib.tier_coeff("scan", "max", "float32") is by_dtype
    assert calib.tier_coeff("scan", "max", "int8") is generic
    # an unknown tier kind predicts 0, never crashes
    assert calib.tier_coeff("nope").local_us(10, 10) == 0.0
    # unmeasured link domains fall back to the shipped defaults
    assert calib.link_coeff("dcn").t0_us == \
        default_calibration().link_coeff("dcn").t0_us


# -- fitting -----------------------------------------------------------------

def test_fit_tier_coeff_recovers_exact_model():
    true = TierCoeff(t0_us=3.0, us_per_byte=2e-4, us_per_record=5e-2)
    n1, n2, b1, b2 = 100, 1000, 16, 256
    fitted = fit_tier_coeff(
        n1=n1, b1=b1, t11_us=true.local_us(n1, b1),
        n2=n2, t21_us=true.local_us(n2, b1),
        b2=b2, t22_us=true.local_us(n2, b2))
    assert fitted.t0_us == pytest.approx(true.t0_us, rel=1e-6)
    assert fitted.us_per_byte == pytest.approx(true.us_per_byte, rel=1e-6)
    assert fitted.us_per_record == pytest.approx(true.us_per_record, rel=1e-6)


def test_fit_clamps_noise_to_nonnegative():
    # timings that DECREASE with size (pure noise) must not fit negative
    # slopes — a fitted table may never predict negative microseconds
    c = fit_tier_coeff(n1=10, b1=4, t11_us=100.0, n2=100, t21_us=50.0,
                       b2=64, t22_us=40.0)
    assert c.t0_us >= 0 and c.us_per_byte >= 0 and c.us_per_record >= 0
    assert c.local_us(10_000, 1024) >= 0
    link = fit_link_coeff(bytes1=100, t1_us=50.0, bytes2=1000, t2_us=10.0)
    assert link.t0_us >= 0 and link.us_per_byte >= 0
    with pytest.raises(ValueError):
        fit_tier_coeff(n1=10, b1=4, t11_us=1, n2=10, t21_us=1, b2=8, t22_us=1)
    with pytest.raises(ValueError):
        fit_link_coeff(bytes1=8, t1_us=1, bytes2=8, t2_us=1)


# -- the quick calibration harness end-to-end --------------------------------

def test_roofline_calibrate_quick_produces_loadable_table(tmp_path):
    """The CI smoke path: --calibrate --quick writes a versioned table the
    planner can consume (merged over the shipped defaults)."""
    import importlib
    roofline = importlib.import_module("benchmarks.roofline")
    out = str(tmp_path / "calib.json")
    calib, path = roofline.calibrate(quick=True, out=out)
    assert path == out
    loaded = load_calibration(out)
    assert loaded is not None and loaded.source == "measured"
    assert loaded.backend == jax.default_backend()
    # measured entries exist for the quick zoo...
    assert "sum|float32" in loaded.tiers["segment_ops"]
    assert "sum|float32" in loaded.tiers["scan"]
    # ...and every tier still has a generic entry (merged over defaults)
    for kind in ("kernel", "segment_ops", "scan", "tree"):
        assert "*" in loaded.tiers[kind]
    # the measured table drives a plan without error
    p = plan_fold(monoids.sum_, jnp.ones((32, 2), jnp.float32),
                  segment_ids=jnp.zeros((32,), jnp.int32), num_segments=4,
                  calibration=loaded)
    assert p.predicted_us > 0


# -- the bench-gate logic in benchmarks/run.py --------------------------------

def test_overlap_rows_gate():
    """check_overlap_rows: auto must track sync_dense, lossy bytes must
    undercut dense bytes — and the gate stays silent when the overlap
    section did not run (no 8-device mesh locally)."""
    import importlib
    run = importlib.import_module("benchmarks.run")

    def rows(auto, sync, dense=None, lossy=None):
        out = [{"name": "overlap_step_us/auto", "us_per_call": auto},
               {"name": "overlap_step_us/sync_dense", "us_per_call": sync}]
        if dense is not None:
            out += [{"name": "overlap_bytes/dense", "us_per_call": dense},
                    {"name": "overlap_bytes/lossy", "us_per_call": lossy}]
        return out

    assert run.check_overlap_rows([]) == []                    # section skipped
    assert run.check_overlap_rows(rows(100.0, 100.0)) == []
    assert run.check_overlap_rows(rows(109.0, 100.0)) == []    # inside 1.10x
    bad = run.check_overlap_rows(rows(150.0, 100.0))
    assert len(bad) == 1 and "auto" in bad[0]
    assert run.check_overlap_rows(rows(100.0, 100.0, 4096.0, 80.0)) == []
    bad = run.check_overlap_rows(rows(100.0, 100.0, 4096.0, 4096.0))
    assert len(bad) == 1 and "bytes" in bad[0]


def test_overlap_step_rows_are_regression_guarded():
    """overlap_step rows ride the same --compare gate as the other hot
    paths: a >tolerance slowdown vs the rolling baseline is a failure."""
    import importlib
    run = importlib.import_module("benchmarks.run")
    assert any(p == "overlap_step" for p in run.GUARDED_PREFIXES)
    old = [{"name": "overlap_step_us/auto", "us_per_call": 100.0}]
    new = [{"name": "overlap_step_us/auto", "us_per_call": 130.0}]
    assert run.compare_rows(new, old) == [
        ("overlap_step_us/auto", 100.0, 130.0)]
    assert run.compare_rows(old, old) == []
