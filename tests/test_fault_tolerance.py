"""Stats-driven fault tolerance: the per-step ShuffleStats record (the same
one the benchmarks emit) feeding the straggler monitor and the elastic
controller.

The property under test is the ISSUE's early-warning story: a slow host
shows up as COLLAPSING OVERLAP (measured overlap fraction far below the
plan's model) while its step time is still inside the timeout threshold —
the monitor flags it immediately, the controller records it as a suspect,
and only a sustained slowdown later escalates to a re-mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import monoids, plan_fold
from repro.core.mapreduce import fold_stats
from repro.runtime.fault_tolerance import (ElasticController,
                                           StragglerMonitor,
                                           checkpoint_interval, plan_remesh)

_SIZES = {"x": 4, "pod": 2}


def _plan(layout="async", n_mb=4, d=1024, lossy=None):
    shape = jax.ShapeDtypeStruct((n_mb, d), jnp.float32)
    return plan_fold(monoids.sum_, shape, mesh_axes=("x", "pod"),
                     layout=layout, axis_sizes=_SIZES, lossy=lossy)


# ---------------------------------------------------------------------------
# fold_stats: the Plan -> ShuffleStats bridge
# ---------------------------------------------------------------------------

def test_fold_stats_reads_async_plan_annotations():
    stats = fold_stats(_plan("async"))
    assert stats.overlap_modeled > 0.0          # the plan promises hiding
    assert stats.shuffle_values == 4            # one crossing per microbatch
    assert stats.overlap_measured is None       # nothing observed yet
    assert stats.overlap_collapse() is None
    got = stats.with_measured(123.0, overlap=0.05)
    assert got.measured_us == 123.0
    np.testing.assert_allclose(got.overlap_collapse(),
                               stats.overlap_modeled - 0.05)


def test_fold_stats_sync_plan_has_no_overlap():
    stats = fold_stats(_plan("scan"))
    assert stats.overlap_modeled == 0.0
    assert stats.shuffle_values == 1            # one crossing, full sum


def test_fold_stats_lossy_compression_ratio():
    stats = fold_stats(_plan("scan", lossy="topk:0.01"))
    assert 0 < stats.lossy_wire_bytes < stats.dense_wire_bytes
    assert stats.compression_ratio() > 1.0
    assert stats.lossy == "topk:0.01"
    dense = fold_stats(_plan("scan"))
    assert dense.compression_ratio() == 1.0


# ---------------------------------------------------------------------------
# StragglerMonitor.observe_stats: overlap collapse leads, timeout trails
# ---------------------------------------------------------------------------

def _host_stats(base, *, measured_us, overlap):
    return [base.with_measured(us, overlap=ov)
            for us, ov in zip(measured_us, overlap)]


def test_collapse_flagged_while_step_time_still_healthy():
    base = fold_stats(_plan("async"))
    modeled = base.overlap_modeled
    mon = StragglerMonitor(4, patience=3, collapse_ratio=0.5)
    # equal step times, but host 2's measured overlap is a tenth of the
    # model: flagged as collapsing on the FIRST step, with slow_hosts empty
    report = mon.observe_stats(_host_stats(
        base, measured_us=[1e6] * 4,
        overlap=[modeled, modeled, 0.1 * modeled, modeled]))
    assert report.collapsing_hosts == [2]
    assert report.slow_hosts == []
    assert report.median_overlap == pytest.approx(modeled)


def test_sustained_slowdown_escalates_to_slow_after_patience():
    base = fold_stats(_plan("async"))
    modeled = base.overlap_modeled
    mon = StragglerMonitor(4, patience=3, collapse_ratio=0.5)
    reports = []
    for _ in range(6):
        reports.append(mon.observe_stats(_host_stats(
            base, measured_us=[1e6, 1e6, 8e6, 1e6],
            overlap=[modeled, modeled, 0.0, modeled])))
    # the collapse signal fires from step 1; the timeout only after the
    # EWMA has crossed the threshold for `patience` consecutive steps
    assert reports[0].collapsing_hosts == [2]
    assert reports[0].slow_hosts == []
    assert reports[-1].slow_hosts == [2]


def test_observe_stats_falls_back_to_modeled_time():
    base = fold_stats(_plan("async"))
    stats = [base.with_measured(2e6), base, base, base]   # 3 hosts silent
    mon = StragglerMonitor(4, patience=1)
    report = mon.observe_stats(stats)
    # silent hosts contribute predicted_us; the loud host's 2s EWMA is
    # far past 1.5x the (tiny) modeled median, so it is flagged at once
    assert report.slow_hosts == [0]


def test_sync_stats_never_flag_collapse():
    base = fold_stats(_plan("scan"))                      # overlap_modeled 0
    mon = StragglerMonitor(2)
    report = mon.observe_stats(_host_stats(
        base, measured_us=[1e6, 1e6], overlap=[0.0, 0.0]))
    assert report.collapsing_hosts == []
    assert report.median_overlap is None


# ---------------------------------------------------------------------------
# ElasticController.ingest: suspects first, re-mesh only on escalation
# ---------------------------------------------------------------------------

def test_ingest_records_suspects_without_remesh():
    base = fold_stats(_plan("async"))
    modeled = base.overlap_modeled
    mon = StragglerMonitor(4, patience=3)
    ctl = ElasticController(64, model_parallel=16, on_remesh=None)
    shape_before = ctl.current.shape
    report = mon.observe_stats(_host_stats(
        base, measured_us=[1e6] * 4,
        overlap=[modeled, modeled, 0.1 * modeled, modeled]))
    assert ctl.ingest(report) is None           # warning only
    assert ctl.suspects == [2]
    assert ctl.current.shape == shape_before


def test_ingest_escalation_downs_host_once():
    base = fold_stats(_plan("async"))
    modeled = base.overlap_modeled
    mon = StragglerMonitor(4, patience=2)
    remeshes = []
    ctl = ElasticController(64, model_parallel=16,
                            on_remesh=lambda p: remeshes.append(p))
    plan = None
    for _ in range(4):
        report = mon.observe_stats(_host_stats(
            base, measured_us=[1e6, 1e6, 9e6, 1e6],
            overlap=[modeled, modeled, 0.0, modeled]))
        out = ctl.ingest(report, devices_per_host=16)
        plan = out or plan
    assert plan is not None and plan.shape == (2, 16)     # 64-16 devices
    assert len(remeshes) == 1                   # the downed host counts once
    assert ctl.suspects == []                   # slow supersedes suspect


def test_plan_remesh_unrecoverable_vs_minimal():
    assert plan_remesh(15, model_parallel=16) is None
    minimal = plan_remesh(16, model_parallel=16)
    assert minimal is not None and minimal.shape == (1, 16)


# ---------------------------------------------------------------------------
# checkpoint cadence: derived, not hard-coded
# ---------------------------------------------------------------------------

def test_checkpoint_interval_young_daly_math():
    # MTBF_system = 24h * 3600 / 1000 nodes = 86.4s;
    # t_opt = sqrt(2 * 30 * 86.4) = 72s; at 2s steps -> 36
    assert checkpoint_interval(2.0, mtbf_hours=24.0, num_nodes=1000,
                               write_time_s=30.0) == 36
    # cadence never drops below one step, whatever the numbers say
    assert checkpoint_interval(1e9, mtbf_hours=1.0, num_nodes=10**6) == 1
    # fewer nodes -> longer system MTBF -> sparser checkpoints
    sparse = checkpoint_interval(2.0, num_nodes=10)
    dense = checkpoint_interval(2.0, num_nodes=1000)
    assert sparse > dense
