"""Property tests: every monoid in the zoo satisfies the monoid laws.

The laws (associativity, two-sided identity, declared commutativity,
structure preservation) are exactly what licenses combiners/in-mapper
combining (paper §2) — so they are the system's core invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or skip-stub when absent

import repro.optim  # noqa: F401  (registers the lossy compression monoids)
from repro.core import monoids, check_laws
from repro.core.monoid import Monoid, MonoidTypeError, check_structure

finite_f = st.floats(min_value=-50, max_value=50, allow_nan=False,
                     allow_infinity=False, width=32)


def arrays(draw, shape, lo=-50, hi=50):
    return jnp.asarray(np.array(
        [draw(finite_f) for _ in range(int(np.prod(shape)))],
        np.float32).reshape(shape))


@st.composite
def float_vectors(draw, n=3, dim=4):
    return [arrays(draw, (dim,)) for _ in range(n)]


@settings(max_examples=25, deadline=None)
@given(float_vectors())
def test_sum_laws(xs):
    check_laws(monoids.sum_, xs)


@settings(max_examples=25, deadline=None)
@given(float_vectors())
def test_max_min_laws(xs):
    check_laws(monoids.max_, xs)
    check_laws(monoids.min_, xs)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(finite_f, st.integers(1, 100)), min_size=3, max_size=3))
def test_mean_laws_and_extract(pairs):
    samples = [(jnp.float32(s), jnp.int32(c)) for s, c in pairs]
    check_laws(monoids.mean, samples)
    # extract(combine(lift(x_i))) == mean(x_i)
    xs = [p[0] for p in pairs]
    lifted = [monoids.mean.lift(jnp.float32(x)) for x in xs]
    acc = monoids.mean.identity_like(lifted[0])
    for l in lifted:
        acc = monoids.mean.combine(acc, l)
    np.testing.assert_allclose(monoids.mean.extract(acc), np.mean(xs), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.lists(finite_f, min_size=1, max_size=20),
                min_size=2, max_size=4))
def test_welford_matches_numpy(groups):
    m = monoids.welford
    acc = None
    allv = []
    for g in groups:
        allv.extend(g)
        arr = jnp.asarray(np.array(g, np.float32))
        part = (jnp.float32(len(g)), jnp.mean(arr), jnp.var(arr) * len(g))
        acc = part if acc is None else m.combine(acc, part)
    out = m.extract(acc)
    np.testing.assert_allclose(float(out["mean"]), np.mean(allv), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(out["var"]), np.var(allv), rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.lists(finite_f, min_size=6, max_size=6))
def test_logsumexp_monoid(vals):
    m = monoids.logsumexp
    samples = [m.lift(jnp.float32(v)) for v in vals]
    check_laws(m, samples, rtol=1e-4, atol=1e-4)
    acc = samples[0]
    for s in samples[1:]:
        acc = m.combine(acc, s)
    np.testing.assert_allclose(float(m.extract(acc)),
                               float(jax.nn.logsumexp(jnp.asarray(vals))),
                               rtol=1e-5, atol=1e-5)


def test_attn_state_monoid_rebracketing():
    """Any chunking of the KV axis yields the same attention output."""
    rng = np.random.default_rng(0)
    S, d = 32, 8
    logits = jnp.asarray(rng.normal(size=(S,)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    m = monoids.attn_state

    def state_of(sl):
        mx = jnp.max(logits[sl])
        e = jnp.exp(logits[sl] - mx)
        return (mx, e.sum(), (e[:, None] * v[sl]).sum(0))

    full = m.extract(state_of(slice(0, S)))
    for chunks in ([8, 8, 8, 8], [16, 16], [4, 12, 16], [1] + [31]):
        acc = m.identity_like(state_of(slice(0, 1)))
        start = 0
        for c in chunks:
            acc = m.combine(acc, state_of(slice(start, start + c)))
            start += c
        np.testing.assert_allclose(np.asarray(m.extract(acc)), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)
    # associativity/identity laws on random states
    samples = [state_of(slice(a, b)) for a, b in [(0, 8), (8, 20), (20, 32)]]
    check_laws(m, samples, rtol=1e-4, atol=1e-4)


def test_affine_scan_is_linear_recurrence():
    """Composition order: fold of (a,b) pairs == serial h = a*h + b."""
    rng = np.random.default_rng(1)
    n = 17
    a = jnp.asarray(rng.uniform(0.5, 1.0, n).astype(np.float32))
    b = jnp.asarray(rng.normal(size=n).astype(np.float32))
    m = monoids.affine_scan
    h = jnp.float32(0.7)
    for i in range(n):
        h = a[i] * h + b[i]
    acc = m.identity_like((a[0], b[0]))
    for i in range(n):
        acc = m.combine(acc, (a[i], b[i]))
    np.testing.assert_allclose(float(acc[0] * 0.7 + acc[1]), float(h), rtol=1e-5)
    # NOT commutative
    s1 = m.combine((a[0], b[0]), (a[1], b[1]))
    s2 = m.combine((a[1], b[1]), (a[0], b[0]))
    assert not np.allclose(s1[1], s2[1])


def test_topk_monoid():
    m = monoids.top_k(3)
    s1 = (jnp.asarray([9., 5., 1.]), jnp.asarray([0, 1, 2], jnp.int32))
    s2 = (jnp.asarray([7., 6., 2.]), jnp.asarray([3, 4, 5], jnp.int32))
    v, i = m.combine(s1, s2)
    np.testing.assert_array_equal(np.asarray(v), [9., 7., 6.])
    np.testing.assert_array_equal(np.asarray(i), [0, 3, 4])
    check_laws(m, [s1, s2], rtol=1e-6)


def test_product_monoid_single_collective_shape():
    m = monoids.product(loss=monoids.mean, mx=monoids.max_)
    a = {"loss": monoids.mean.lift(jnp.float32(2.0)), "mx": jnp.float32(5.0)}
    b = {"loss": monoids.mean.lift(jnp.float32(4.0)), "mx": jnp.float32(3.0)}
    out = m.extract(m.combine(a, b))
    assert float(out["loss"]) == 3.0 and float(out["mx"]) == 5.0


def test_structure_check_rejects_shape_change():
    bad = Monoid(name="bad", combine=lambda a, b: jnp.concatenate([a, b]),
                 identity_fn=lambda *, example=None: jnp.zeros((2,)))
    with pytest.raises(MonoidTypeError):
        check_structure(bad, jnp.zeros((2,)), jnp.zeros((2,)))


# ---------------------------------------------------------------------------
# discovery-driven law suite: EVERY registered monoid, including the lossy
# compression monoids optim/compress.py registers on import.  CI runs this
# file as its own named step, so "monoid X broke the laws" is the failure
# headline, not a line buried in the tier-1 run.
# ---------------------------------------------------------------------------

def test_no_registered_monoid_ships_law_unchecked():
    missing = monoids.missing_law_samples()
    assert not missing, (
        f"monoids registered WITHOUT law samples: {missing}. Every "
        "register_monoid() call must pass a zero-arg sample provider — a "
        "monoid whose laws are never checked cannot license combiners, "
        "re-bracketing, or the async fold's re-ordering.")


@pytest.mark.parametrize("name", sorted(monoids.REGISTRY))
def test_registered_monoid_laws(name):
    m = monoids.REGISTRY[name]
    provider = monoids.law_samples_for(name)
    assert provider is not None, f"{name}: no law samples registered"
    samples = provider()
    assert len(samples) >= 3, (
        f"{name}: associativity needs >= 3 distinct operands, got "
        f"{len(samples)}")
    check_laws(m, samples)


def test_law_breaking_monoid_fails_the_suite():
    """Subtraction is not associative — the exact check the suite runs on
    every registered monoid must reject it (the deliberate red test: if
    this passes, the law step is checking nothing)."""
    bad = Monoid(name="bad_subtract", combine=lambda a, b: a - b,
                 identity_fn=lambda *, example=None: jnp.zeros(
                     jnp.shape(example) if example is not None else ()))
    with pytest.raises(AssertionError):
        check_laws(bad, [jnp.float32(1.0), jnp.float32(2.0),
                         jnp.float32(3.0)])


def test_registry_rejects_silent_shadowing():
    with pytest.raises(ValueError):
        monoids.register_monoid(monoids.sum_, lambda: [])
