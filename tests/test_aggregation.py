"""Folding machinery: tree/scan folds, segment folds, byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import monoids, tree_fold, scan_fold, fold_map, segment_fold, tree_bytes
from repro.core.aggregation import allreduce_wire_bytes


@pytest.mark.parametrize("n", [1, 2, 7, 16, 33])
def test_tree_fold_equals_scan_fold(n):
    rng = np.random.default_rng(n)
    xs = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    t = tree_fold(monoids.sum_, xs)
    s = scan_fold(monoids.sum_, xs)
    np.testing.assert_allclose(np.asarray(t), np.asarray(s), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(t), xs.sum(0), rtol=1e-5)


def test_tree_fold_noncommutative_order():
    """affine_scan is order-sensitive: folds must preserve sequence order."""
    rng = np.random.default_rng(0)
    n = 13
    a = jnp.asarray(rng.uniform(0.5, 1.0, n).astype(np.float32))
    b = jnp.asarray(rng.normal(size=n).astype(np.float32))
    t = tree_fold(monoids.affine_scan, (a, b))
    s = scan_fold(monoids.affine_scan, (a, b))
    np.testing.assert_allclose(np.asarray(t[0]), np.asarray(s[0]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(t[1]), np.asarray(s[1]), rtol=1e-4, atol=1e-5)


def test_fold_map_strategies_match():
    xs = jnp.arange(24, dtype=jnp.float32)
    fn = lambda x: x * 2 + 1
    a = fold_map(monoids.mean, fn, xs, strategy="scan")
    b = fold_map(monoids.mean, fn, xs, strategy="tree")
    np.testing.assert_allclose(float(monoids.mean.extract(a)),
                               float(monoids.mean.extract(b)), rtol=1e-6)
    np.testing.assert_allclose(float(monoids.mean.extract(a)),
                               float(jnp.mean(fn(xs))), rtol=1e-6)


@pytest.mark.parametrize("impl", ["auto", "onehot", "scan"])
def test_segment_fold_impls_agree(impl):
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=(50, 4)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 7, 50).astype(np.int32))
    out = segment_fold(monoids.sum_, vals, segs, 7, impl=impl)
    oracle = jax.ops.segment_sum(vals, segs, num_segments=7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_segment_fold_generic_monoid():
    """max monoid through the generic serial path."""
    rng = np.random.default_rng(2)
    vals = jnp.asarray(rng.normal(size=(30,)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, 4, 30).astype(np.int32))
    out = segment_fold(monoids.max_, vals, segs, 4)
    oracle = jax.ops.segment_max(vals, segs, num_segments=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=1e-6)


def test_tree_bytes_and_wire_model():
    t = {"a": jnp.zeros((4, 4), jnp.float32), "b": jnp.zeros((8,), jnp.bfloat16)}
    assert tree_bytes(t) == 4 * 4 * 4 + 8 * 2
    assert allreduce_wire_bytes(1000, 1) == 0
    assert allreduce_wire_bytes(1000, 4, algorithm="ring") == 1500
    assert allreduce_wire_bytes(1000, 4, algorithm="gather") == 3000
