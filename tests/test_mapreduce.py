"""The paper's algorithms as executable artifacts (Algorithms 1-5)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MapReduceJob, MonoidTypeError, STRATEGIES,
                        algorithm2_combiner, average_by_key_job,
                        cooccurrence_stripes_job, monoids, validate_combiner,
                        word_count_job)


@pytest.fixture(scope="module")
def records():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 8, 96)
    vals = rng.normal(size=96).astype(np.float32)
    oracle = np.array([vals[keys == k].mean() if (keys == k).any() else 0.0
                       for k in range(8)])
    return ({"key": jnp.asarray(keys), "value": jnp.asarray(vals)}, oracle)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("num_shards", [1, 4])
def test_mean_by_key_all_strategies(records, strategy, num_shards):
    """Algorithms 1, 3 and 4 all compute the same mean-by-key."""
    recs, oracle = records
    job = average_by_key_job(8)
    out = np.asarray(job.run_local(recs, strategy=strategy,
                                   num_shards=num_shards))
    np.testing.assert_allclose(out, oracle, atol=1e-5)


def test_algorithm2_rejected(records):
    """The paper's Algorithm 2 combiner (int -> (sum,count)) violates the
    combiner contract and the engine rejects it."""
    job = average_by_key_job(8)
    with pytest.raises(MonoidTypeError):
        validate_combiner(job.monoid, jnp.float32(1.0), algorithm2_combiner)


def test_legal_combiner_accepted():
    validate_combiner(monoids.mean, monoids.mean.lift(jnp.float32(1.0)))


def test_shuffle_accounting_ordering(records):
    """The paper's efficiency claim: bytes(naive) >= bytes(combiner) ==
    bytes(in_mapper); materialization(in_mapper) < materialization(combiner)."""
    recs, _ = records
    job = average_by_key_job(8)
    st = {s: job.stats(recs, strategy=s, num_shards=4) for s in STRATEGIES}
    assert st["naive"].shuffle_bytes_mapreduce >= st["combiner"].shuffle_bytes_mapreduce
    assert st["combiner"].shuffle_bytes_mapreduce == st["in_mapper"].shuffle_bytes_mapreduce
    assert st["in_mapper"].intermediate_values < st["combiner"].intermediate_values
    assert st["naive"].reduction_vs_naive() == 1.0
    assert st["in_mapper"].reduction_vs_naive() > 1.0


def test_word_count(records):
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 50, 400)
    job = word_count_job(50)
    for s in STRATEGIES:
        out = np.asarray(job.run_local(jnp.asarray(toks), strategy=s,
                                       num_shards=4))
        np.testing.assert_array_equal(out, np.bincount(toks, minlength=50))


def test_stripes_cooccurrence_job():
    """Algorithm 5: windowed co-occurrence via the stripes monoid."""
    rng = np.random.default_rng(2)
    vocab, window, n = 16, 2, 64
    toks = rng.integers(0, vocab, n)
    wins = np.stack([toks[i - window:i + window + 1]
                     for i in range(window, n - window)])
    job = cooccurrence_stripes_job(vocab, window)
    out = np.asarray(job.run_local(jnp.asarray(wins), strategy="in_mapper",
                                   num_shards=4))
    # oracle: count neighbors within the window for each interior center
    oracle = np.zeros((vocab, vocab), np.int64)
    for i in range(window, n - window):
        for off in range(-window, window + 1):
            if off != 0:
                oracle[toks[i], toks[i + off]] += 1
    np.testing.assert_array_equal(out, oracle)


def test_strategies_agree_on_random_monoid_jobs():
    """max-by-key with the max monoid (non-additive path)."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 5, 64)
    vals = rng.normal(size=64).astype(np.float32)

    def mapper(rec):
        return rec["key"], rec["value"]

    job = MapReduceJob(mapper=mapper, monoid=monoids.max_, num_keys=5)
    recs = {"key": jnp.asarray(keys), "value": jnp.asarray(vals)}
    outs = [np.asarray(job.run_local(recs, strategy=s, num_shards=4))
            for s in STRATEGIES]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-6)
    oracle = np.array([vals[keys == k].max() if (keys == k).any() else -np.inf
                       for k in range(5)])
    np.testing.assert_allclose(outs[0], oracle, atol=1e-6)
