"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptConfig, adamw_update, clip_by_global_norm,
                         global_norm, init_opt_state, schedule)
from repro.optim.compress import (compressed_bytes, init_error_state,
                                  int8_compress, int8_decompress,
                                  topk_compress, topk_decompress)
from repro.core.aggregation import grad_accum_fold


def test_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                    min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(schedule(cfg, jnp.int32(10))), 1e-3, rtol=1e-5)
    assert float(schedule(cfg, jnp.int32(5))) < 1e-3
    np.testing.assert_allclose(float(schedule(cfg, jnp.int32(100))), 1e-4, rtol=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = init_opt_state(params)
    cfg = OptConfig(peak_lr=0.2, warmup_steps=1, decay_steps=400,
                    weight_decay=0.0, clip_norm=100.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, cfg)
        params = {"w": opt["master"]["w"]}   # use fp32 master for the probe
    assert float(loss(params)) < 1e-2


def test_grad_accum_fold_equals_full_batch():
    """In-mapper combining over microbatches == one big batch (Sum monoid)."""
    w = jnp.asarray([1.0, 2.0])
    xs = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4, 2)).astype(np.float32))

    def loss_and_grad(p, mb):
        def f(p):
            return jnp.sum(jnp.square(mb @ p))
        l, g = jax.value_and_grad(f)(p)
        return {"loss": l}, g

    metrics, grads = grad_accum_fold(loss_and_grad, w, xs)
    flat = xs.reshape(-1, 2)
    want = jax.grad(lambda p: jnp.sum(jnp.square(flat @ p)))(w)
    np.testing.assert_allclose(np.asarray(grads), np.asarray(want), rtol=1e-4)


def test_topk_error_feedback_sums_to_truth():
    """EF invariant: applied + residual == accumulated true gradient."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    err = init_error_state(g)
    applied = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for _ in range(5):
        comp, err = topk_compress(g, err, ratio=0.1)
        applied += topk_decompress(comp, g)["w"]
        total += g["w"]
    np.testing.assert_allclose(np.asarray(applied + err["w"]),
                               np.asarray(total), rtol=1e-4, atol=1e-5)
    assert compressed_bytes(comp) < 64 * 4


def test_int8_compress_roundtrip_accuracy():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    err = init_error_state(g)
    comp, err = int8_compress(g, err)
    deq = int8_decompress(comp, g)["w"]
    scale = float(jnp.max(jnp.abs(g["w"])))
    assert float(jnp.max(jnp.abs(deq - g["w"]))) <= scale / 127 + 1e-6
    np.testing.assert_allclose(np.asarray(deq + err["w"]), np.asarray(g["w"]),
                               rtol=1e-5, atol=1e-6)


def test_ef_sgd_converges_on_quadratic():
    """Top-k EF-SGD reaches the optimum despite 90% sparsification."""
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    Q = A @ A.T / 16 + jnp.eye(16)
    w = {"w": jnp.asarray(rng.normal(size=(16,)).astype(np.float32))}
    err = init_error_state(w)
    loss = lambda p: 0.5 * p["w"] @ Q @ p["w"]
    for _ in range(300):
        g = jax.grad(loss)(w)
        comp, err = topk_compress(g, err, ratio=0.1)
        upd = topk_decompress(comp, w)
        w = {"w": w["w"] - 0.05 * upd["w"]}
    assert float(loss(w)) < 1e-3
