"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptConfig, adamw_update, clip_by_global_norm,
                         global_norm, init_opt_state, schedule)
from repro.optim.compress import (LossySpec, blocktopk_compress,
                                  compressed_bytes, init_error_state,
                                  int8_compress, int8_decompress,
                                  topk_compress, topk_decompress)
from repro.core.aggregation import grad_accum_fold


def test_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                    min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(schedule(cfg, jnp.int32(10))), 1e-3, rtol=1e-5)
    assert float(schedule(cfg, jnp.int32(5))) < 1e-3
    np.testing.assert_allclose(float(schedule(cfg, jnp.int32(100))), 1e-4, rtol=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = init_opt_state(params)
    cfg = OptConfig(peak_lr=0.2, warmup_steps=1, decay_steps=400,
                    weight_decay=0.0, clip_norm=100.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, cfg)
        params = {"w": opt["master"]["w"]}   # use fp32 master for the probe
    assert float(loss(params)) < 1e-2


def test_grad_accum_fold_equals_full_batch():
    """In-mapper combining over microbatches == one big batch (Sum monoid)."""
    w = jnp.asarray([1.0, 2.0])
    xs = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4, 2)).astype(np.float32))

    def loss_and_grad(p, mb):
        def f(p):
            return jnp.sum(jnp.square(mb @ p))
        l, g = jax.value_and_grad(f)(p)
        return {"loss": l}, g

    metrics, grads = grad_accum_fold(loss_and_grad, w, xs)
    flat = xs.reshape(-1, 2)
    want = jax.grad(lambda p: jnp.sum(jnp.square(flat @ p)))(w)
    np.testing.assert_allclose(np.asarray(grads), np.asarray(want), rtol=1e-4)


def test_topk_error_feedback_sums_to_truth():
    """EF invariant: applied + residual == accumulated true gradient."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    err = init_error_state(g)
    applied = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for _ in range(5):
        comp, err = topk_compress(g, err, ratio=0.1)
        applied += topk_decompress(comp, g)["w"]
        total += g["w"]
    np.testing.assert_allclose(np.asarray(applied + err["w"]),
                               np.asarray(total), rtol=1e-4, atol=1e-5)
    assert compressed_bytes(comp) < 64 * 4


def test_int8_compress_roundtrip_accuracy():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    err = init_error_state(g)
    comp, err = int8_compress(g, err)
    deq = int8_decompress(comp, g)["w"]
    scale = float(jnp.max(jnp.abs(g["w"])))
    assert float(jnp.max(jnp.abs(deq - g["w"]))) <= scale / 127 + 1e-6
    np.testing.assert_allclose(np.asarray(deq + err["w"]), np.asarray(g["w"]),
                               rtol=1e-5, atol=1e-6)


def test_topk_kclamp_edge_cases():
    """ratio on a tiny leaf must never request k=0 or k>size (regression:
    int(3 * 0.01) == 0 used to produce an empty top_k)."""
    err = {"w": jnp.zeros((3,))}
    for ratio in (0.01, 0.5, 1.0):
        comp, new_e = topk_compress({"w": jnp.asarray([1.0, -2.0, 0.5])},
                                    err, ratio=ratio)
        k = comp["w"]["values"].shape[0]
        assert 1 <= k <= 3, (ratio, k)
    # ratio=1.0 keeps everything: the round-trip is exact and EF is zero
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    comp, new_e = topk_compress(g, err, ratio=1.0)
    np.testing.assert_array_equal(np.asarray(topk_decompress(comp, g)["w"]),
                                  np.asarray(g["w"]))
    np.testing.assert_array_equal(np.asarray(new_e["w"]), np.zeros(3))


def test_blocktopk_non_divisible_sizes():
    """Block selection on sizes that don't divide the block length: indices
    stay in range and the EF invariant holds."""
    rng = np.random.default_rng(3)
    for size in (5, 17, 100):
        g = {"w": jnp.asarray(rng.normal(size=(size,)).astype(np.float32))}
        err = init_error_state(g)
        comp, new_e = blocktopk_compress(g, err, ratio=0.3)
        idx = np.asarray(comp["w"]["idx"])
        assert idx.min() >= 0 and idx.max() < size, (size, idx)
        applied = topk_decompress(comp, g)["w"]
        np.testing.assert_allclose(np.asarray(applied + new_e["w"]),
                                   np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("method", ["topk", "blocktopk", "int8"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ef_exact_for_param_dtype(method, dtype):
    """The EF residual is computed against what the receiver applies AFTER
    the cast to the parameter dtype — so applied + residual == truth to the
    last bit, in bf16 as in f32 (regression: the residual used to be taken
    against the f32 values, leaking the bf16 rounding every step)."""
    rng = np.random.default_rng(4)
    spec = LossySpec.parse(method if method == "int8" else f"{method}:0.25")
    g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32)).astype(dtype)}
    err = init_error_state(g)
    acc_f32 = jnp.asarray(np.asarray(g["w"].astype(jnp.float32)))
    comp, new_e = spec.compress(g, err)
    applied = spec.decompress(comp, g)["w"].astype(jnp.float32)
    diff = np.abs(np.asarray(applied + new_e["w"] - acc_f32))
    assert diff.max() == 0.0, (method, dtype, diff.max())


def test_lossy_spec_parse_and_wire_bytes():
    assert LossySpec.parse("topk:0.1") == LossySpec("topk", 0.1)
    assert LossySpec.parse("int8").method == "int8"
    assert LossySpec.parse(LossySpec("blocktopk", 0.5)).ratio == 0.5
    with pytest.raises(ValueError):
        LossySpec.parse("gzip:0.1")
    with pytest.raises(ValueError):
        LossySpec("topk", 0.0)
    with pytest.raises(TypeError):
        LossySpec.parse(3)
    like = {"w": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    assert LossySpec.parse("topk:0.01").wire_bytes(like) == 10 * 8
    assert LossySpec.parse("int8").wire_bytes(like) == 1000 + 4
    # the annotation must beat the dense crossing for it to be worth wiring
    assert LossySpec.parse("topk:0.01").wire_bytes(like) < 1000 * 4


def test_opt_state_with_ef_persists_through_update():
    """The steps.py pattern: pop 'ef' around adamw_update (which rebuilds
    the state dict) and push the new residual back in."""
    params = {"w": jnp.asarray([1.0, -1.0])}
    opt = init_opt_state(params, with_ef=True)
    assert "ef" in opt
    ef = opt.pop("ef")
    g = {"w": jnp.asarray([0.5, 0.25])}
    _, new_opt, _ = adamw_update(g, opt, OptConfig())
    assert "ef" not in new_opt          # adamw_update drops unknown keys
    new_opt["ef"] = ef
    assert set(new_opt) == {"step", "m", "v", "master", "ef"}


def test_ef_sgd_converges_on_quadratic():
    """Top-k EF-SGD reaches the optimum despite 90% sparsification."""
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    Q = A @ A.T / 16 + jnp.eye(16)
    w = {"w": jnp.asarray(rng.normal(size=(16,)).astype(np.float32))}
    err = init_error_state(w)
    loss = lambda p: 0.5 * p["w"] @ Q @ p["w"]
    for _ in range(300):
        g = jax.grad(loss)(w)
        comp, err = topk_compress(g, err, ratio=0.1)
        upd = topk_decompress(comp, w)
        w = {"w": w["w"] - 0.05 * upd["w"]}
    assert float(loss(w)) < 1e-3
