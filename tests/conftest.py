import os
import sys

# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see 1 device (the 512-device placeholder mesh
# exists only inside launch/dryrun.py and the subprocess distributed tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
