import os
import sys

# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see 1 device (the 512-device placeholder mesh
# exists only inside launch/dryrun.py and the subprocess distributed tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Pin the planner to the SHIPPED default cost-model table: a developer's
# ~/.cache/repro/calib.json (measured on their machine) must not flip the
# tier choices the suite asserts.  Tests that exercise the disk cache set
# REPRO_CALIB themselves (monkeypatch / subprocess env).
os.environ.setdefault("REPRO_CALIB", "default")
