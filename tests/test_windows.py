"""Windowed streaming analytics: differential oracles and stateful tests.

* Two-stacks :class:`SlidingWindow` vs a brute-force O(n*w) recompute over
  the monoid zoo (sum/max/mean-pair/CMS/HLL + a non-commutative matrix
  monoid), unkeyed and keyed (per-user), hypothesis-driven with
  deterministic fallbacks.
* Decay monoids: registered law samples, exact half-life semantics, and a
  RED test proving a decay monoid with a broken identity fails the law
  suite.
* Sessionization vs a pure-Python reference: boundaries and per-session
  folds bit-for-bit (int32), including the cross-host ``sync_stats`` merge
  under 8 fake devices.
* :class:`WindowedMetrics` fed by the toy continuous engine end to end.
"""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.core import monoids
from repro.core.monoid import Monoid, check_laws
from repro.data.windows import (SlidingWindow, TumblingWindow,
                                WindowedMetrics, session_fold, sessionize,
                                tumbling_fold, tumbling_ids)
from test_distributed import PRELUDE, run_distributed
from test_serving import toy_backend, toy_engine

# ---------------------------------------------------------------------------
# the zoo: monoid factory + raw-item generator, per name
# ---------------------------------------------------------------------------

# 2x2 matrix product: non-commutative, so any window implementation that
# reorders combines (or folds the evicted element back in) fails loudly
_MAT2 = Monoid(
    name="mat2", combine=lambda a, b: a @ b,
    identity_fn=lambda *, example=None: jnp.eye(2, dtype=jnp.float32),
    commutative=False)


def _floats(rng, n):
    return [jnp.asarray(v, jnp.float32)
            for v in rng.integers(-8, 8, n).tolist()]


def _ints(rng, n):
    return [jnp.asarray(v, jnp.int32)
            for v in rng.integers(0, 100, n).tolist()]


def _mats(rng, n):
    # unimodular-ish integer matrices keep products exact in float32
    return [jnp.asarray([[1.0, float(a)], [0.0, 1.0]]) if i % 2 == 0
            else jnp.asarray([[1.0, 0.0], [float(a), 1.0]])
            for i, a in enumerate(rng.integers(-3, 4, n).tolist())]


ZOO = {
    "sum": (lambda: monoids.sum_, _floats),
    "max": (lambda: monoids.max_, _floats),
    "mean": (lambda: monoids.mean, _floats),
    "cms": (lambda: monoids.count_min(2, 64), _ints),
    "hll": (lambda: monoids.hyperloglog(4), _ints),
    "mat2": (lambda: _MAT2, _mats),
}


def brute_window(m, lifted, i, size):
    """Oracle: fold the last ``size`` lifted items ending at ``i``, in
    stream order, from the identity — O(w) combines per query."""
    acc = m.identity_like(lifted[0])
    for it in lifted[max(0, i - size + 1): i + 1]:
        acc = m.combine(acc, it)
    return acc


def assert_window_matches_bruteforce(m, items, size):
    lifted = [m.lift(x) for x in items]
    w = SlidingWindow(m, size)
    for i, it in enumerate(lifted):
        w.push(it)
        want = brute_window(m, lifted, i, size)
        assert m.equal(w.query(), want, rtol=1e-5, atol=1e-5), \
            (m.name, size, i)
    # each element flips at most once: O(1) amortized combines per event
    assert w.flip_combines <= w.pushes


# ---------------------------------------------------------------------------
# sliding window == brute force (deterministic sweep, always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_sliding_window_matches_bruteforce(name):
    make, gen = ZOO[name]
    rng = np.random.default_rng(hash(name) % 2**31)
    for size in (1, 3, 7):
        assert_window_matches_bruteforce(make(), gen(rng, 19), size)


@pytest.mark.parametrize("name", ["sum", "max", "cms"])
def test_keyed_sliding_windows_match_bruteforce(name):
    """Per-user windows: one SlidingWindow per key, each == its own oracle
    over only that user's events."""
    make, gen = ZOO[name]
    m = make()
    rng = np.random.default_rng(3)
    users = rng.integers(0, 3, 40).tolist()
    items = [m.lift(x) for x in gen(rng, 40)]
    wins, per_user = {}, {}
    for u, it in zip(users, items):
        w = wins.setdefault(u, SlidingWindow(m, 4))
        seen = per_user.setdefault(u, [])
        seen.append(it)
        w.push(it)
        want = brute_window(m, seen, len(seen) - 1, 4)
        assert m.equal(w.query(), want, rtol=1e-5, atol=1e-5), (name, u)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_sliding_window_matches_bruteforce_hypothesis(data):
    """Arbitrary streams and window sizes over the whole zoo."""
    name = data.draw(st.sampled_from(sorted(ZOO)))
    make, _ = ZOO[name]
    m = make()
    size = data.draw(st.integers(min_value=1, max_value=8))
    if name in ("cms", "hll"):
        raw = data.draw(st.lists(st.integers(0, 200), min_size=1,
                                 max_size=20))
        items = [jnp.asarray(v, jnp.int32) for v in raw]
    elif name == "mat2":
        raw = data.draw(st.lists(st.integers(-3, 3), min_size=1,
                                 max_size=16))
        items = [jnp.asarray([[1.0, float(v)], [0.0, 1.0]]) if i % 2
                 else jnp.asarray([[1.0, 0.0], [float(v), 1.0]])
                 for i, v in enumerate(raw)]
    else:
        raw = data.draw(st.lists(st.integers(-8, 8), min_size=1,
                                 max_size=20))
        items = [jnp.asarray(v, jnp.float32) for v in raw]
    assert_window_matches_bruteforce(m, items, size)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_keyed_sliding_windows_hypothesis(data):
    m = monoids.sum_
    events = data.draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(-8, 8)),
        min_size=1, max_size=30))
    size = data.draw(st.integers(min_value=1, max_value=5))
    wins, per_user = {}, {}
    for u, v in events:
        it = jnp.asarray(v, jnp.float32)
        w = wins.setdefault(u, SlidingWindow(m, size))
        seen = per_user.setdefault(u, [])
        seen.append(it)
        w.push(it)
        want = brute_window(m, seen, len(seen) - 1, size)
        assert m.equal(w.query(), want, rtol=1e-5, atol=1e-5)


def test_sliding_window_basics():
    w = SlidingWindow(monoids.sum_, 3,
                      example=jnp.zeros((), jnp.float32))
    assert float(np.asarray(w.query())) == 0.0      # identity when empty
    for v in (1, 2, 3, 4):
        w.push(jnp.asarray(float(v)))
    assert len(w) == 3
    assert float(np.asarray(w.extract())) == 2 + 3 + 4
    with pytest.raises(ValueError):
        SlidingWindow(monoids.sum_, 0)
    with pytest.raises(ValueError):
        SlidingWindow(monoids.sum_, 2).query()      # no identity yet


# ---------------------------------------------------------------------------
# hypothesis stateful machine: window vs a deque reference
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    from collections import deque

    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)

    class WindowMachine(RuleBasedStateMachine):
        """Random push/evict/query interleavings vs a deque of raw values."""

        @initialize(size=st.integers(1, 6))
        def setup(self, size):
            self.size = size
            self.win = SlidingWindow(monoids.sum_, size,
                                     example=jnp.zeros((), jnp.float32))
            self.ref = deque(maxlen=size)

        @rule(v=st.integers(-10, 10))
        def push(self, v):
            self.win.push(jnp.asarray(v, jnp.float32))
            self.ref.append(v)

        @rule()
        def evict(self):
            if self.ref:
                self.win.evict()
                self.ref.popleft()

        @invariant()
        def window_matches_reference(self):
            if hasattr(self, "ref"):
                assert len(self.win) == len(self.ref)
                assert float(np.asarray(self.win.query())) == sum(self.ref)

    WindowMachine.TestCase.settings = settings(max_examples=10,
                                               stateful_step_count=20,
                                               deadline=None)
    TestWindowMachine = WindowMachine.TestCase


# ---------------------------------------------------------------------------
# decay monoids
# ---------------------------------------------------------------------------

DECAY_NAMES = ("decayed_sum(hl=16)", "decayed_count(hl=16)",
               "decayed_lru(hl=16)")


def test_decay_monoids_registered_with_law_samples():
    assert monoids.missing_law_samples() == []
    for name in DECAY_NAMES:
        assert name in monoids.REGISTRY, name
        check_laws(monoids.REGISTRY[name], monoids.law_samples_for(name)())


def test_decay_semantics_half_life():
    m = monoids.decayed_sum(16.0)
    s = m.combine(m.lift((1.0, 0.0)), m.lift((1.0, 16.0)))
    # the t=0 unit halved once, the t=16 unit fresh
    assert np.isclose(float(monoids.decayed_value(s, 16.0, 16.0)), 1.5)
    # re-anchoring the query another half-life halves the whole thing
    assert np.isclose(float(monoids.decayed_value(s, 32.0, 16.0)), 0.75)
    lru = monoids.decayed_lru(16.0)
    s = lru.combine(lru.lift((4.0, 0.0)), lru.lift((1.0, 16.0)))
    # max(4 halved, 1 fresh) = 2: older-but-larger still wins
    assert np.isclose(float(monoids.decayed_value(s, 16.0, 16.0)), 2.0)


def test_decay_fold_is_order_insensitive():
    m = monoids.decayed_sum(8.0)
    events = [(1.0, 3.0), (2.0, -1.0), (0.5, 10.0), (4.0, 7.0)]

    def fold(order):
        acc = m.identity_like(m.lift(events[0]))
        for i in order:
            acc = m.combine(acc, m.lift(events[i]))
        return float(monoids.decayed_value(acc, 10.0, 8.0))

    want = fold(range(len(events)))
    for order in ([3, 1, 0, 2], [2, 0, 3, 1]):
        assert np.isclose(fold(order), want, rtol=1e-5)


def test_broken_decay_identity_is_rejected():
    """RED: an identity anchored at t=0 (instead of -inf) decays pre-epoch
    samples on combine with the unit — the law suite must catch it."""
    samples = [(jnp.asarray(v, jnp.float32), jnp.asarray(t, jnp.float32))
               for v, t in ((1.0, -5.0), (2.0, -2.0), (0.5, -9.0))]
    check_laws(monoids.decayed_sum(8.0), samples)   # the real one is lawful
    broken = dataclasses.replace(
        monoids.decayed_sum(8.0), name="broken_decay",
        identity_fn=lambda *, example=None: (jnp.zeros(()), jnp.zeros(())))
    with pytest.raises(AssertionError, match="identity"):
        check_laws(broken, samples)
    with pytest.raises(ValueError):
        monoids.decayed_sum(0.0)                    # non-positive half-life


# ---------------------------------------------------------------------------
# tumbling windows
# ---------------------------------------------------------------------------

def test_tumbling_stream_matches_batch_fold():
    rng = np.random.default_rng(5)
    n = 60
    ts = np.sort(rng.uniform(0.0, 12.0, n)).astype(np.float32)
    vals = rng.integers(-10, 10, n).astype(np.float32)
    tw = TumblingWindow(monoids.sum_, 2.0)
    closed = []
    for v, t in zip(vals, ts):
        closed += tw.push(jnp.asarray(v), float(t))
    closed += tw.flush()

    ref = {}
    for v, t in zip(vals, ts):
        ref[int(t // 2.0)] = ref.get(int(t // 2.0), 0.0) + float(v)
    assert {r.index: float(np.asarray(r.value)) for r in closed} == ref
    for r in closed:
        assert r.end - r.start == 2.0

    table = np.asarray(tumbling_fold(monoids.sum_, jnp.asarray(vals), ts,
                                     width=2.0, num_windows=6))
    np.testing.assert_allclose(table,
                               [ref.get(i, 0.0) for i in range(6)])


def test_tumbling_fold_masks_out_of_range_events():
    vals = jnp.asarray([1.0, 10.0, 100.0, 1000.0])
    ts = np.array([-0.5, 0.5, 1.5, 99.0])      # first and last out of range
    table = np.asarray(tumbling_fold(monoids.sum_, vals, ts, width=1.0,
                                     num_windows=2))
    np.testing.assert_allclose(table, [10.0, 100.0])
    ids = np.asarray(tumbling_ids(ts, width=1.0))
    assert ids.tolist() == [-1, 0, 1, 99]


def test_tumbling_rejects_time_travel():
    tw = TumblingWindow(monoids.sum_, 1.0)
    tw.push(jnp.asarray(1.0), 5.0)
    with pytest.raises(ValueError):
        tw.push(jnp.asarray(1.0), 3.0)
    with pytest.raises(ValueError):
        TumblingWindow(monoids.sum_, 0.0)


# ---------------------------------------------------------------------------
# sessionization
# ---------------------------------------------------------------------------

def reference_sessionize(users, ts, gap):
    """Independent pure-Python reference: dense ids in order of session
    birth, new session on first-sight or gap expiry."""
    sids, state, nxt = [], {}, 0
    for u, t in zip(users, ts):
        prev = state.get(u)
        if prev is None or t - prev[1] > gap:
            state[u] = [nxt, t]
            nxt += 1
        else:
            state[u][1] = t
        sids.append(state[u][0])
    return sids, nxt


def _session_case(seed, n=64, users=4):
    rng = np.random.default_rng(seed)
    us = rng.integers(0, users, n)
    ts = np.cumsum(rng.uniform(0.0, 3.0, n))
    vals = rng.integers(-50, 50, n).astype(np.int32)
    return us, ts, vals


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sessionize_matches_reference(seed):
    us, ts, _ = _session_case(seed)
    sids, n = sessionize(us, ts, gap=4.0)
    want, wn = reference_sessionize(us.tolist(), ts.tolist(), 4.0)
    assert sids.tolist() == want
    assert n == wn


def test_sessionize_gap_boundary_and_validation():
    # exactly-gap spacing stays in session; strictly-greater splits
    sids, n = sessionize([7, 7, 7], [0.0, 2.0, 4.0 + 1e-9], gap=2.0)
    assert sids.tolist() == [0, 0, 1] and n == 2
    with pytest.raises(ValueError):
        sessionize([1, 1], [2.0, 1.0], gap=1.0)     # unordered stream
    with pytest.raises(ValueError):
        sessionize([[1]], [1.0], gap=1.0)           # not 1-D


@pytest.mark.parametrize("seed", [3, 4])
def test_session_fold_bit_for_bit(seed):
    """Per-session int32 sums through the planner == Python ints exactly."""
    us, ts, vals = _session_case(seed)
    sids, n = sessionize(us, ts, gap=4.0)
    table = np.asarray(session_fold(monoids.sum_, jnp.asarray(vals), sids, n))
    ref = [0] * n
    for s, v in zip(sids.tolist(), vals.tolist()):
        ref[s] += int(v)
    assert table.tolist() == ref                    # bit-for-bit, no allclose


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_sessionize_matches_reference_hypothesis(data):
    events = data.draw(st.lists(
        st.tuples(st.integers(0, 3),
                  st.floats(0.0, 5.0, allow_nan=False)),
        min_size=1, max_size=40))
    gap = data.draw(st.floats(0.5, 6.0, allow_nan=False))
    users = [u for u, _ in events]
    ts = np.cumsum([dt for _, dt in events])
    sids, n = sessionize(users, ts, gap=gap)
    want, wn = reference_sessionize(users, ts.tolist(), gap)
    assert sids.tolist() == want and n == wn
    assert sorted(set(sids.tolist())) == list(range(n))     # dense ids


def test_session_fold_syncs_across_hosts():
    """8 fake hosts each fold their shard of the session table, then ONE
    sync_stats merge == the global pure-Python per-session sums exactly."""
    run_distributed(PRELUDE + """
from repro.core import monoids
from repro.data.stats import sync_stats
from repro.data.windows import session_fold, sessionize
rng = np.random.default_rng(7)
n = 128
users = rng.integers(0, 6, n)
ts = np.cumsum(rng.uniform(0.0, 3.0, n))
vals = rng.integers(-20, 20, n).astype(np.int32)
sids, nsess = sessionize(users, ts, gap=4.0)
ref = [0] * nsess
for s, v in zip(sids.tolist(), vals.tolist()):
    ref[s] += int(v)
P = jax.sharding.PartitionSpec

def body(v, s):
    local = session_fold(monoids.sum_, v, s, nsess)
    return sync_stats(monoids.sum_, local, ("data",))

out = jax.shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                    out_specs=P(), check_vma=False)(
    jnp.asarray(vals), jnp.asarray(sids, jnp.int32))
assert np.asarray(out).tolist() == ref, (np.asarray(out), ref)
print("ok")
""")


# ---------------------------------------------------------------------------
# WindowedMetrics: unit semantics + the toy engine end to end
# ---------------------------------------------------------------------------

def _event(kind, user, t, result=None):
    from repro.runtime.engine import StreamEvent
    return StreamEvent(uid=0, kind=kind, slot=0, step=0, time_s=t,
                       user=user, result=result)


def _done(user, t, latency, ttft, ntok):
    from repro.runtime.engine import RequestResult
    res = RequestResult(uid=0, slot=0, prompt_len=1, bucket=4, user=user,
                        tokens=list(range(ntok)), logprob_sum=0.0,
                        stopped=True, stop_step=1, ttft_s=ttft,
                        latency_s=latency)
    return _event("done", user, t, result=res)


def test_windowed_metrics_semantics():
    m = WindowedMetrics(window=2, half_life_s=60.0, tumble_s=1.0)
    m.observe(_event("token", user=1, t=0.0))
    m.observe(_event("token", user=1, t=60.0))
    assert np.isclose(m.user_token_rate(1, 60.0), 1.5)      # one half-life
    assert m.user_token_rate(2, 60.0) == 0.0
    m.observe(_done(1, 1.0, latency=0.4, ttft=0.1, ntok=3))
    m.observe(_done(1, 2.0, latency=0.2, ttft=0.3, ntok=5))
    m.observe(_done(1, 3.0, latency=0.6, ttft=0.5, ntok=7))
    row = m.user_window(1)                # window=2: only the last two
    assert row["requests"] == 2
    assert np.isclose(row["latency_s"], 0.4)
    assert np.isclose(row["ttft_s"], 0.4)
    assert np.isclose(row["tokens"], 6.0)
    assert m.fleet_tokens() == 2.0        # one per token event
    summary = m.summary(now=60.0)
    assert set(summary) == {1}
    assert np.isclose(summary[1]["token_rate"], 1.5)


def test_windowed_metrics_consumes_engine_events():
    """End to end: every engine stream event folds into the consumer —
    fleet tumbling count == generated tokens, users partition requests."""
    metrics = WindowedMetrics(window=4, half_life_s=60.0, tumble_s=0.5)
    eng = toy_engine(num_slots=2)
    eng.subscribe(metrics.observe)
    uids = {i: eng.submit([1 + i, 2, 3], user=i % 2) for i in range(5)}
    list(eng.run(max_steps=200))
    total_tokens = sum(len(eng.result(u).tokens) for u in uids.values())
    assert metrics.fleet_tokens() == total_tokens
    # + one "done" and one prefix-cache "cache" event per request
    assert metrics.events == total_tokens + 2 * len(uids)
    assert metrics.users() == [0, 1]
    summary = metrics.summary(now=time.perf_counter())
    assert summary[0]["requests"] == 3 and summary[1]["requests"] == 2
    for u in (0, 1):
        assert summary[u]["token_rate"] > 0
        assert summary[u]["latency_s"] >= summary[u]["ttft_s"] >= 0
    want_mean = np.mean([len(eng.result(uids[i]).tokens)
                         for i in range(5) if i % 2 == 0])
    assert np.isclose(summary[0]["tokens"], want_mean)


def test_engine_consumers_constructor_path():
    from repro.serving import ServeConfig
    from repro.runtime.engine import ContinuousEngine
    seen = []
    eng = ContinuousEngine(
        toy_backend(),
        ServeConfig(num_slots=2, prefill_buckets=(4, 8), max_new_tokens=3,
                    eos_id=-7),
        consumers=[seen.append])
    eng.submit([1, 2], user=9)
    list(eng.run(max_steps=50))
    assert seen and all(ev.user == 9 for ev in seen)
    kinds = [ev.kind for ev in seen]
    assert kinds.count("done") == 1 and kinds.count("token") == 3
