"""Radix prefix KV cache + prefix-aware chunked prefill (PR 10).

The load-bearing properties:

* trie exactness — lookup returns EXACTLY the longest cached block-aligned
  strict prefix, payloads intact (differential oracle vs a pure-Python LCP
  reference, deterministic + hypothesis);
* monoid bookkeeping — the folded stats table's bytes column always sums to
  the host byte mirror, and eviction order follows the decayed-LRU score
  (recency can beat frequency at short half-lives);
* serving exactness — a prefix-hit admission decodes bit-identically to a
  cold one (cached KV rows ARE the recomputed rows for position-indexed
  caches), batched same-bucket admissions share ONE prefill program, and
  the compile count stays within the declared bound.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.core import monoids
from repro.data.windows import WindowedMetrics
from repro.models.attention import cache_row_update, cache_span_update
from repro.serving import (ContinuousEngine, PrefixCache, PrefixCacheConfig,
                           ServeConfig)
from test_serving import drain, toy_backend, toy_engine

BLOCK = 2


def token_payload(prompt, block=BLOCK):
    """Payload generator whose block i IS the token block (one leaf)."""
    return lambda i: [np.asarray(prompt[i * block:(i + 1) * block],
                                 np.int64)]


def oracle_hit_blocks(inserted, prompt, block=BLOCK):
    """Pure-Python reference: longest cached strict block prefix of
    ``prompt`` given the full-block prefixes of every inserted prompt."""
    limit = max(len(prompt) - 1, 0) // block
    best = 0
    for p in inserted:
        lcp = 0
        while lcp < min(len(p), len(prompt)) and p[lcp] == prompt[lcp]:
            lcp += 1
        best = max(best, min(lcp // block, len(p) // block, limit))
    return best


class ManualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# the trie: differential oracle
# ---------------------------------------------------------------------------

class TestTrieOracle:
    def test_lookup_is_longest_strict_block_prefix(self):
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=64))
        inserted = [[1, 2, 3, 4, 5], [1, 2, 3, 9], [7, 8]]
        for p in inserted:
            c.insert(p, token_payload(p))
        for prompt in ([1, 2, 3, 4, 5, 6], [1, 2, 3, 9, 9], [1, 2], [1, 3],
                       [7, 8, 1], [9], [1, 2, 3, 4], [1, 2, 3]):
            hit = c.lookup(prompt)
            want = oracle_hit_blocks(inserted, prompt)
            assert hit.length == want * BLOCK, prompt
            assert len(hit.blocks) == len(hit.node_ids) == want
            for i, blk in enumerate(hit.blocks):
                np.testing.assert_array_equal(
                    blk[0], prompt[i * BLOCK:(i + 1) * BLOCK])

    def test_shared_prefixes_share_nodes(self):
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=64))
        assert c.insert([1, 2, 3, 4], token_payload([1, 2, 3, 4])) == 2
        # the [1,2] node already exists: only the divergent block is new
        assert c.insert([1, 2, 9, 9], token_payload([1, 2, 9, 9])) == 1
        assert c.node_count == 3

    def test_max_blocks_caps_insert_depth(self):
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=64))
        p = [1, 2, 3, 4, 5, 6]
        c.insert(p, token_payload(p), max_blocks=2)
        assert c.lookup(p + [7]).length == 2 * BLOCK

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.lists(st.integers(0, 2), min_size=1, max_size=9),
                    min_size=1, max_size=10))
    def test_trie_matches_oracle_on_random_traces(self, prompts):
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=256))
        inserted = []
        for p in prompts:
            hit = c.lookup(p)
            assert hit.length == oracle_hit_blocks(inserted, p) * BLOCK
            for i, blk in enumerate(hit.blocks):
                np.testing.assert_array_equal(
                    blk[0], p[i * BLOCK:(i + 1) * BLOCK])
            c.insert(p, token_payload(p))
            inserted.append(p)
        assert c.accounted_bytes() == c.total_bytes


# ---------------------------------------------------------------------------
# monoid bookkeeping: byte accounting + decayed-LRU eviction order
# ---------------------------------------------------------------------------

class TestStatsFold:
    def test_bytes_column_tracks_host_mirror(self):
        clock = ManualClock()
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=4),
                        clock=clock)
        for p in ([1, 2], [3, 4], [5, 6, 7, 8]):
            c.insert(p, token_payload(p))
        assert c.total_bytes > 0
        assert c.accounted_bytes() == c.total_bytes
        c.evict(2)
        assert c.accounted_bytes() == c.total_bytes
        assert c.stats.evictions == 2

    def test_eviction_order_follows_decayed_score(self):
        clock = ManualClock()
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=8,
                                          half_life_s=1e6), clock=clock)
        # three leaves inserted together; touch B once and C three times
        for p in ([1, 1], [2, 2], [3, 3]):
            c.insert(p, token_payload(p))
        clock.t = 10.0
        c.lookup([2, 2, 0])
        for _ in range(3):
            c.lookup([3, 3, 0])
        # near-infinite half life: score == touch count; A < B < C
        assert c.evict(1) == 1 and c.lookup([1, 1, 0]).length == 0
        assert c.evict(1) == 1 and c.lookup([2, 2, 0]).length == 0
        assert c.lookup([3, 3, 0]).length == BLOCK

    def test_recency_beats_frequency_at_short_half_life(self):
        clock = ManualClock()
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=8,
                                          half_life_s=10.0), clock=clock)
        c.insert([1, 1], token_payload([1, 1]))
        for _ in range(5):
            c.lookup([1, 1, 0])          # 6 touches at t=0
        clock.t = 100.0
        c.insert([2, 2], token_payload([2, 2]))   # 1 touch at t=100
        # 6 * 2^-10 << 1: the stale-but-popular node goes first
        c.evict(1)
        assert c.lookup([1, 1, 0]).length == 0
        assert c.lookup([2, 2, 0]).length == BLOCK

    def test_capacity_eviction_protects_insert_path(self):
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=2))
        c.insert([1, 2, 3, 4], token_payload([1, 2, 3, 4]))
        assert c.node_count == 2
        # full: inserting a 2-block chain must evict, but never its own
        # freshly-created parent — the chain lands intact
        c.insert([5, 6, 7, 8], token_payload([5, 6, 7, 8]))
        assert c.lookup([5, 6, 7, 8, 9]).length == 2 * BLOCK
        assert c.node_count == 2
        assert c.accounted_bytes() == c.total_bytes

    def test_max_bytes_budget(self):
        p = [1, 2, 3, 4]
        one_block = int(np.asarray(p[:BLOCK], np.int64).nbytes)
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=16,
                                          max_bytes=2 * one_block))
        c.insert(p, token_payload(p))
        assert c.total_bytes == 2 * one_block
        c.insert([9, 9], token_payload([9, 9]))      # evicts to fit
        assert c.total_bytes <= 2 * one_block
        assert c.accounted_bytes() == c.total_bytes

    def test_one_fold_per_flush_and_compile_counts(self):
        c = PrefixCache(PrefixCacheConfig(block=BLOCK, capacity=8,
                                          events_per_fold=4))
        for p in ([1, 2], [3, 4], [5, 6]):
            c.insert(p, token_payload(p))
        assert c.flush_stats() == 1                  # 3 events, one chunk
        for p in ([1, 2, 9], [3, 4, 9], [5, 6, 9], [1, 2, 8], [3, 4, 8]):
            c.lookup(p)
        assert c.flush_stats() == 2                  # 5 events, two chunks
        counts = c.compile_counts()
        assert counts["prefix_stats_fold"] == 1      # fixed-shape: ONE program
        assert c.flush_stats() == 0

    def test_cache_stats_monoid_registered(self):
        assert monoids.missing_law_samples() == []
        m = monoids.cache_stats(32.0)
        assert m.name in monoids.REGISTRY


# ---------------------------------------------------------------------------
# engine integration: exactness, batching, compile bound
# ---------------------------------------------------------------------------

class TestEnginePrefix:
    def test_warm_hit_bit_identical_to_cold(self):
        p = [1, 2, 3, 4, 5, 6, 7]
        warm = toy_engine(num_slots=1, prefix_block=2)
        cold = toy_engine(num_slots=1, prefix_cache=False)
        u1 = warm.submit(p, seed=5)
        drain(warm)
        u2 = warm.submit(p, seed=5)          # second pass hits the trie
        drain(warm)
        uc = cold.submit(p, seed=5)
        drain(cold)
        assert warm.prefix.stats.hits == 1
        assert warm.result(u2).bucket < cold.result(uc).bucket  # suffix bucket
        for uid in (u1, u2):
            got, ref = warm.result(uid), cold.result(uc)
            assert got.tokens == ref.tokens
            assert got.logprob_sum == ref.logprob_sum            # bitwise
            assert got.stopped == ref.stopped

    def test_partial_hit_and_divergent_suffix(self):
        warm = toy_engine(num_slots=1, prefix_block=2)
        cold = toy_engine(num_slots=1, prefix_cache=False)
        a, b = [1, 2, 3, 4, 5, 6], [1, 2, 3, 4, 9, 9, 9]
        warm.submit(a, seed=1)
        drain(warm)
        u = warm.submit(b, seed=2)           # hits the shared 4-token prefix
        drain(warm)
        uc = cold.submit(b, seed=2)
        drain(cold)
        assert warm.prefix.stats.hit_tokens == 4
        assert warm.result(u).tokens == cold.result(uc).tokens
        assert warm.result(u).logprob_sum == cold.result(uc).logprob_sum

    def test_batched_admission_one_prefill_program(self):
        eng = toy_engine(num_slots=4, prefill_batch=4)
        prompts = [[1, 2, 3], [4, 5, 6], [7, 8, 9], [10, 11, 1]]
        uids = [eng.submit(p, seed=20 + i) for i, p in enumerate(prompts)]
        evs = eng.step()
        assert eng.stats.prefill_calls == 1              # ONE (4, bucket) call
        assert eng.stats.batched_admissions == 4
        assert len([e for e in evs
                    if e.kind == "token" and e.index == 0]) == 4
        drain(eng)
        from test_serving import solo_result
        for i, (p, uid) in enumerate(zip(prompts, uids)):
            ref = solo_result(p, 20 + i)
            got = eng.result(uid)
            assert got.tokens == ref.tokens
            assert got.logprob_sum == ref.logprob_sum
        counts = eng.compile_counts()
        assert counts["prefill_k4_b4"] == 1

    def test_mixed_buckets_group_separately(self):
        eng = toy_engine(num_slots=4, prefill_batch=4, prefix_cache=False)
        for p in ([1, 2], [3, 4], [1, 2, 3, 4, 5, 6], [7, 8, 9, 10, 11, 12]):
            eng.submit(p)
        eng.step()
        assert eng.stats.prefill_calls == 2              # one per bucket
        assert eng.stats.batched_admissions == 4

    def test_cache_events_feed_windowed_metrics(self):
        metrics = WindowedMetrics(window=8, tumble_s=0.5)
        eng = toy_engine(num_slots=2, prefix_block=2)
        eng.subscribe(metrics.observe)
        p = [1, 2, 3, 4, 5]
        eng.submit(p)
        drain(eng)
        eng.submit(p)
        drain(eng)
        fp = metrics.fleet_prefix()
        assert fp["prompt_tokens"] == 2 * len(p)
        assert fp["hit_tokens"] == 4                     # second admission
        assert fp["bytes_saved"] > 0
        assert fp["hit_rate"] == pytest.approx(4 / 10)

    def test_compile_bound_over_churny_warm_trace(self):
        eng = toy_engine(num_slots=3, prefill_buckets=(2, 4, 8),
                         prefill_batch=2, prefix_block=2)
        rng = np.random.default_rng(7)
        shared = rng.integers(1, 12, 6).tolist()
        for i in range(14):
            if rng.random() < 0.6:
                extra = rng.integers(1, 12, int(rng.integers(1, 3))).tolist()
                p = shared + extra
            else:
                p = rng.integers(1, 12, int(rng.integers(1, 9))).tolist()
            eng.submit(p, max_new_tokens=int(rng.integers(1, 6)))
        drain(eng, max_steps=500)
        assert eng.prefix.stats.hits > 0
        counts = eng.compile_counts()
        for key, n in counts.items():
            assert n <= 1, (key, n)
        assert sum(counts.values()) <= eng.compile_bound()

    def test_prefix_disabled_on_non_positional_backend(self):
        backend = toy_backend()
        backend.prefix_sharing = False
        eng = ContinuousEngine(backend, ServeConfig(
            num_slots=2, prefill_buckets=(4, 8), max_new_tokens=4,
            eos_id=-7))
        assert eng.prefix is None
        u = eng.submit([1, 2, 3])
        drain(eng)
        assert len(eng.result(u).tokens) == 4

    def test_accounting_stays_exact_under_engine_churn(self):
        eng = toy_engine(num_slots=2, prefix_block=2, prefix_capacity=4)
        rng = np.random.default_rng(3)
        for _ in range(10):
            eng.submit(rng.integers(1, 12,
                                    int(rng.integers(2, 8))).tolist())
        drain(eng, max_steps=500)
        assert eng.prefix.stats.evictions > 0            # capacity 4 churns
        assert eng.prefix.accounted_bytes() == eng.prefix.total_bytes


# ---------------------------------------------------------------------------
# the span scatter primitive
# ---------------------------------------------------------------------------

class TestCacheSpanUpdate:
    def test_matches_loop_reference_vector_pos(self):
        rng = np.random.default_rng(0)
        cache = rng.normal(size=(3, 10, 4)).astype(np.float32)
        new = rng.normal(size=(3, 5, 4)).astype(np.float32)
        pos = np.array([0, 2, 5], np.int32)
        got = np.asarray(cache_span_update(jnp.asarray(cache),
                                           jnp.asarray(new),
                                           jnp.asarray(pos)))
        want = cache.copy()
        for b in range(3):
            want[b, pos[b]:pos[b] + 5] = new[b]
        np.testing.assert_array_equal(got, want)

    def test_scalar_pos_and_stacked_axis(self):
        rng = np.random.default_rng(1)
        cache = rng.normal(size=(2, 3, 10, 4)).astype(np.float32)  # (n,B,S,H)
        new = rng.normal(size=(2, 3, 4, 4)).astype(np.float32)
        got = np.asarray(cache_span_update(jnp.asarray(cache),
                                           jnp.asarray(new),
                                           jnp.int32(3), seq_axis=2))
        want = cache.copy()
        want[:, :, 3:7] = new
        np.testing.assert_array_equal(got, want)

    def test_single_row_delegates_to_row_update(self):
        cache = jnp.zeros((2, 6), jnp.int32)
        out = cache_row_update(cache, jnp.asarray([[5], [7]], jnp.int32),
                               jnp.asarray([1, 4], jnp.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      [[0, 5, 0, 0, 0, 0],
                                       [0, 0, 0, 0, 7, 0]])
