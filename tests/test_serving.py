"""Continuous-batching serve engine: rolling slots, bucketed compilation,
streaming decode (repro.serving facade).

The load-bearing properties:

* slot-reuse exactness — a request admitted into a freed slot mid-decode
  produces tokens/metrics bit-identical to decoding it alone (per-slot
  cache positions make a reused slot indistinguishable from a fresh one);
* bounded compilation — the number of distinct jitted shapes over any
  arrival trace is bounded by the declared prefill-bucket ladder;
* the admission queue is FIFO, streaming is per-request ordered, and the
  deprecated batch-to-completion shim reports the same metrics.

Most tests drive a tiny deterministic toy backend (no model) so the slot
machinery is exercised in milliseconds; one class runs the real smoke
model end-to-end.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.models.attention import cache_row_update, decode_positions
from repro.runtime import RequestBatcher
from repro.serving import ContinuousEngine, EngineBackend, Request, ServeConfig

VOCAB = 13
TOY_SEQ = 32


def toy_decode(params, cache, cur):
    """Deterministic position- and history-dependent toy LM.

    Row-independent by construction (everything is per-row), uses the cache
    position exactly like real attention does: reads only history at
    positions <= its own pos, so stale KV from a prior occupant is
    unreadable iff the engine's slot handoff is sound.
    """
    batch = cur.shape[0]
    pos = cache["pos"]
    posb = decode_positions(pos, batch)[:, 0]
    hist = cache_row_update(cache["hist"], cur, pos)
    valid = jnp.arange(hist.shape[1])[None, :] <= posb[:, None]
    s = jnp.sum(hist * valid, axis=1)
    tgt = (s * params["a"] + posb * params["b"]) % VOCAB
    logits = 5.0 * jax.nn.one_hot(tgt, VOCAB) + 0.01 * jnp.arange(VOCAB)
    return logits.astype(jnp.float32), {"pos": pos + 1, "hist": hist}


def toy_init_cache(batch, pos_per_slot):
    pos0 = jnp.zeros((batch,) if pos_per_slot else (), jnp.int32)
    return {"pos": pos0, "hist": jnp.zeros((batch, TOY_SEQ), jnp.int32)}


def toy_backend():
    return EngineBackend(decode=toy_decode, init_cache=toy_init_cache,
                         params={"a": jnp.int32(3), "b": jnp.int32(7)},
                         vocab_size=VOCAB)


def toy_engine(**overrides):
    kw = dict(num_slots=2, prefill_buckets=(4, 8), max_new_tokens=6,
              eos_id=-7)        # eos unreachable: retirement is budget-driven
    kw.update(overrides)
    return ContinuousEngine(toy_backend(), ServeConfig(**kw))


def drain(engine, max_steps=200):
    return list(engine.run(max_steps=max_steps))


def solo_result(prompt, uid_seed, **overrides):
    """The same request decoded alone in a fresh single-slot engine."""
    overrides = dict(overrides)
    max_new = overrides.pop("max_new", None)
    eng = toy_engine(num_slots=1, **overrides)
    uid = eng.submit(prompt, max_new_tokens=max_new, seed=uid_seed)
    drain(eng)
    return eng.result(uid)


# ---------------------------------------------------------------------------
# config + admission queue
# ---------------------------------------------------------------------------

class TestServeConfig:
    def test_bucket_ladder_validation(self):
        with pytest.raises(ValueError):
            ServeConfig(prefill_buckets=())
        with pytest.raises(ValueError):
            ServeConfig(prefill_buckets=(16, 8))
        with pytest.raises(ValueError):
            ServeConfig(prefill_buckets=(8, 8))
        with pytest.raises(ValueError):
            ServeConfig(num_slots=0)

    def test_bucket_for_and_max_seq(self):
        cfg = ServeConfig(prefill_buckets=(4, 8, 16), max_new_tokens=5)
        assert cfg.bucket_for(1) == 4
        assert cfg.bucket_for(4) == 4
        assert cfg.bucket_for(5) == 8
        assert cfg.bucket_for(16) == 16
        with pytest.raises(ValueError):
            cfg.bucket_for(17)
        assert cfg.max_seq == 16 + 5
        assert cfg.max_prompt == 16

    def test_submit_validation(self):
        eng = toy_engine()
        with pytest.raises(ValueError):
            eng.submit([])
        with pytest.raises(ValueError):
            eng.submit(list(range(9)))          # > largest bucket (8)
        with pytest.raises(ValueError):
            eng.submit([1], max_new_tokens=0)
        with pytest.raises(ValueError):
            eng.submit([1], max_new_tokens=99)  # > config ceiling


class TestBatcherTake:
    def test_take_is_fifo_and_immediate(self):
        b = RequestBatcher(max_batch_size=4, max_wait_s=100.0)
        uids = [b.submit([i]) for i in range(5)]
        got = b.take(2)
        assert [r.uid for r in got] == uids[:2]   # oldest first, no waiting
        assert len(b) == 3
        assert [r.uid for r in b.take(10)] == uids[2:]
        assert b.take(3) == ()
        with pytest.raises(ValueError):
            b.take(-1)


# ---------------------------------------------------------------------------
# the rolling engine (toy backend)
# ---------------------------------------------------------------------------

class TestContinuousEngine:
    def test_slot_reused_mid_decode(self):
        """The tentpole: a freed slot is handed to a waiting request while
        another request is still decoding, and both come out exact."""
        eng = toy_engine(num_slots=2)
        a = eng.submit([1, 2, 3], max_new_tokens=6)
        b = eng.submit([4, 5], max_new_tokens=2)
        c = eng.submit([6, 7, 8], max_new_tokens=2)   # queued: no free slot
        events = drain(eng)

        done = {e.uid: e for e in events if e.kind == "done"}
        admit = {e.uid: e for e in events
                 if e.kind == "token" and e.index == 0}
        # c inherits b's slot, is admitted after b retires and BEFORE a
        # finishes — continuous batching, not batch-to-completion
        assert admit[c].slot == done[b].result.slot
        assert done[b].step <= admit[c].step < done[a].step
        assert eng.stats.slot_reuses >= 1
        # and every request matches its solo decode exactly
        for uid, prompt, max_new in [(a, [1, 2, 3], 6), (b, [4, 5], 2),
                                     (c, [6, 7, 8], 2)]:
            ref = solo_result(prompt, uid, max_new=max_new)
            got = eng.result(uid)
            assert got.tokens == ref.tokens
            assert got.logprob_sum == ref.logprob_sum   # bitwise
            assert got.stopped == ref.stopped

    def test_admission_is_fifo(self):
        eng = toy_engine(num_slots=1, max_new_tokens=1)
        uids = [eng.submit([i + 1]) for i in range(4)]
        events = drain(eng)
        done_order = [e.uid for e in events if e.kind == "done"]
        assert done_order == uids

    def test_streaming_order_and_ttft(self):
        eng = toy_engine()
        uids = [eng.submit([1, 2]), eng.submit([3, 4, 5, 6, 7])]
        events = drain(eng)
        for uid in uids:
            toks = [e for e in events if e.kind == "token" and e.uid == uid]
            assert [e.index for e in toks] == list(range(len(toks)))
            assert toks[0].ttft_s is not None and toks[0].ttft_s >= 0
            assert all(e.ttft_s is None for e in toks[1:])
            done = [e for e in events if e.kind == "done" and e.uid == uid]
            assert len(done) == 1
            res = done[0].result
            assert res.tokens == [e.token for e in toks]
            assert res.ttft_s == toks[0].ttft_s
            assert eng.result(uid) is res

    def test_eos_stops_and_frees_slot(self):
        # find a token the toy model actually generates, make it the eos
        probe = toy_engine(num_slots=1)
        u = probe.submit([1, 2, 3])
        drain(probe)
        eos = probe.result(u).tokens[-1]
        eng = toy_engine(num_slots=1, eos_id=eos)
        u2 = eng.submit([1, 2, 3])
        drain(eng)
        res = eng.result(u2)
        assert res.stopped
        assert res.tokens[-1] == eos
        assert len(res.tokens) <= len(probe.result(u).tokens)
        assert eng.num_active == 0

    def test_metrics_table_matches_results(self):
        eng = toy_engine()
        uids = [eng.submit([1, 2, 3, 4]), eng.submit([5, 6])]
        drain(eng)
        for uid in uids:
            res = eng.result(uid)
            # logprob_sum is the fold of per-token log-softmax picks
            assert np.isfinite(res.logprob_sum)
            assert len(res.tokens) >= 1
            assert res.latency_s >= res.ttft_s >= 0

    def test_temperature_sampling_is_request_keyed(self):
        """temperature>0: per-(seed, token-index) PRNG streams make a
        request's samples independent of slot assignment and neighbours."""
        prompts = [[1, 2], [3, 4, 5], [6]]
        eng = toy_engine(temperature=1.0, num_slots=2)
        uids = [eng.submit(p, seed=100 + i) for i, p in enumerate(prompts)]
        drain(eng)
        for i, (p, uid) in enumerate(zip(prompts, uids)):
            ref = solo_result(p, 100 + i, temperature=1.0)
            assert eng.result(uid).tokens == ref.tokens

    def test_recompile_count_bounded_by_bucket_ladder(self):
        """Zero recompilation beyond the declared ladder: one step program,
        one slot-write/prefix-gather program per admission size k, one
        prefill program per (k, bucket) — over a churny trace of mixed
        lengths, budgets, and slot handoffs."""
        eng = toy_engine(num_slots=3, prefill_buckets=(2, 4, 8))
        rng = np.random.default_rng(0)
        for i in range(12):
            plen = int(rng.integers(1, 9))
            eng.submit(rng.integers(1, VOCAB, plen).tolist(),
                       max_new_tokens=int(rng.integers(1, 7)))
        drain(eng, max_steps=500)
        counts = eng.compile_counts()
        assert eng.stats.slot_reuses > 0
        assert counts["step"] == 1
        for key, n in counts.items():
            assert n <= 1, (key, n)
        assert sum(counts.values()) <= eng.compile_bound()

    def test_fixed_trace_matches_solo(self):
        """Deterministic fallback for the hypothesis property below."""
        trace = [([1, 2, 3, 4, 5, 6], 4), ([7, 8], 6), ([9], 1),
                 ([10, 11, 12], 3), ([1, 3, 5, 7], 6), ([2, 4], 2)]
        eng = toy_engine(num_slots=2)
        uids = [eng.submit(p, max_new_tokens=m) for p, m in trace]
        drain(eng)
        for uid, (p, m) in zip(uids, trace):
            ref = solo_result(p, uid, max_new=m)
            got = eng.result(uid)
            assert (got.tokens, got.logprob_sum, got.stopped) == \
                (ref.tokens, ref.logprob_sum, ref.stopped)


@settings(max_examples=15, deadline=None)
@given(st.lists(
    st.tuples(st.lists(st.integers(1, VOCAB - 1), min_size=1, max_size=8),
              st.integers(1, 6)),
    min_size=1, max_size=8))
def test_arrival_trace_bit_identical_to_solo(trace):
    """Property: ANY arrival trace through the rolling engine yields
    per-request (tokens, logprob sum, stop) bit-identical to decoding each
    request alone — slot reuse is unobservable in the results."""
    eng = toy_engine(num_slots=2)
    uids = [eng.submit(p, max_new_tokens=m) for p, m in trace]
    drain(eng, max_steps=1000)
    for uid, (p, m) in zip(uids, trace):
        ref = solo_result(p, uid, max_new=m)
        got = eng.result(uid)
        assert got.tokens == ref.tokens
        assert got.logprob_sum == ref.logprob_sum
        assert got.stop_step >= 0 and ref.stop_step >= 0
        assert got.stopped == ref.stopped


# ---------------------------------------------------------------------------
# real model substrate (smoke config) + deprecated shim
# ---------------------------------------------------------------------------

class TestRealModelServing:
    @pytest.fixture(scope="class")
    def engine_factory(self):
        from repro.serving import build_engine

        def make(**overrides):
            kw = dict(arch="qwen3-0.6b", num_slots=2, prefill_buckets=(8,),
                      max_new_tokens=4)
            kw.update(overrides)
            return build_engine(ServeConfig(**kw))

        return make

    @pytest.fixture(scope="class")
    def solo_engine(self, engine_factory):
        # ONE single-slot reference engine, reused across prompts (its slot
        # hands off between them — solo decode is itself slot reuse)
        return engine_factory(num_slots=1)

    def solo(self, solo_engine, prompt):
        uid = solo_engine.submit(prompt)
        drain(solo_engine)
        return solo_engine.result(uid)

    def test_engine_matches_solo_decode(self, engine_factory, solo_engine):
        prompts = [[5, 9, 2, 7], [11, 3], [6, 6, 6], [8, 1, 4, 4, 2]]
        eng = engine_factory()
        uids = [eng.submit(p) for p in prompts]
        drain(eng)
        assert eng.stats.slot_reuses >= 1
        counts = eng.compile_counts()
        assert counts["step"] == 1 and counts["write_k1"] == 1
        assert counts["prefill_k1_b8"] == 1
        assert sum(counts.values()) <= eng.compile_bound()
        for p, uid in zip(prompts, uids):
            ref, got = self.solo(solo_engine, p), eng.result(uid)
            assert got.tokens == ref.tokens
            assert got.logprob_sum == ref.logprob_sum   # bitwise
            assert got.stopped == ref.stopped

    def test_prefix_hit_bit_identical_to_cold(self, engine_factory):
        """A real-model admission served from the prefix cache decodes
        bit-identically to a cold prefill of the same prompt: RoPE keys KV
        rows to absolute positions, so cached rows ARE recomputed rows."""
        prompt = [5, 9, 2, 7, 11, 3]
        warm = engine_factory(num_slots=1, prefill_buckets=(4, 8),
                              prefix_block=2)
        cold = engine_factory(num_slots=1, prefill_buckets=(4, 8),
                              prefix_cache=False)
        u1 = warm.submit(prompt)
        drain(warm)
        u2 = warm.submit(prompt)
        drain(warm)
        uc = cold.submit(prompt)
        drain(cold)
        assert warm.prefix is not None and warm.prefix.stats.hits == 1
        ref = cold.result(uc)
        for uid in (u1, u2):
            got = warm.result(uid)
            assert got.tokens == ref.tokens
            assert got.logprob_sum == ref.logprob_sum   # bitwise
        assert warm.result(u2).bucket == 4              # suffix bucket

    def test_run_batched_decode_shim(self, engine_factory, solo_engine):
        from repro.runtime import DecodeBatch
        from repro.serving import run_batched_decode

        prompts = [[5, 9, 2, 7], [11, 3, 8]]
        reqs = tuple(Request(uid=i, prompt=tuple(p), max_new_tokens=4)
                     for i, p in enumerate(prompts))
        batch = DecodeBatch(requests=reqs, num_slots=2)
        eng = engine_factory()
        with pytest.warns(DeprecationWarning):
            res = run_batched_decode(eng, batch)
        assert res.tokens.shape == (2, 4)
        # shim metrics identical to each request decoded alone
        for i, p in enumerate(prompts):
            ref = self.solo(solo_engine, p)
            assert res.tokens[i, : len(ref.tokens)].tolist() == ref.tokens
            assert res.metrics["logprob_sum"][i] == np.float32(ref.logprob_sum)
            assert res.metrics["tokens"][i] == len(ref.tokens)
            assert res.metrics["stopped"][i] == ref.stopped
        assert res.decode_steps == eng.stats.steps
        assert res.prefill_s > 0 and res.decode_s > 0
