"""Runtime layer: serving batcher + fault-tolerance control plane
(simulated signals/timings/clocks)."""
import signal

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime import (DecodeBatch, ElasticController, PreemptionHandler,
                           Request, RequestBatcher, StragglerMonitor,
                           checkpoint_interval, plan_remesh)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# request batcher
# ---------------------------------------------------------------------------

def test_batcher_flushes_on_full_batch():
    b = RequestBatcher(max_batch_size=3, max_wait_s=10.0, clock=FakeClock())
    assert b.flush() is None
    b.submit([1, 2]); b.submit([3])
    assert not b.ready() and b.flush() is None       # partial + not waited
    b.submit([4, 5, 6]); b.submit([7])
    assert b.ready()
    batch = b.flush()
    assert [r.prompt for r in batch.requests] == [(1, 2), (3,), (4, 5, 6)]
    assert len(b) == 1                                # FIFO remainder queued
    assert batch.num_slots == 3


def test_batcher_max_wait_flushes_partial_batch():
    clk = FakeClock()
    b = RequestBatcher(max_batch_size=8, max_wait_s=0.5, clock=clk)
    b.submit([1]); b.submit([2, 3])
    clk.t = 0.4
    assert not b.ready()
    clk.t = 0.51                                      # oldest waited out
    assert b.ready()
    batch = b.flush()
    assert len(batch) == 2 and batch.num_slots == 8   # ragged, not re-shaped
    assert batch.slot_valid.tolist() == [True, True] + [False] * 6
    assert b.stats.waited_flushes == 1
    assert b.stats.fill_rate(8) == pytest.approx(2 / 8)
    # a FORCED partial drain is not a wait-policy fire
    b.submit([4]); b.flush(force=True)
    assert b.stats.waited_flushes == 1


def test_batch_slots_are_segment_ids_and_pack_is_ragged():
    reqs = tuple(Request(uid=i, prompt=tuple(range(1, n + 1)),
                         max_new_tokens=4) for i, n in enumerate([3, 1, 5]))
    batch = DecodeBatch(requests=reqs, num_slots=4)
    np.testing.assert_array_equal(batch.segment_ids, [0, 1, 2, 3])
    toks, lengths, valid = batch.pack(pad_id=0)
    assert toks.shape == (4, 5)
    np.testing.assert_array_equal(lengths, [3, 1, 5, 0])
    np.testing.assert_array_equal(valid.sum(1), [3, 1, 5, 0])
    assert (toks[~valid] == 0).all()
    np.testing.assert_array_equal(toks[2], [1, 2, 3, 4, 5])
    np.testing.assert_array_equal(batch.max_new(), [4, 4, 4, 0])


# ---------------------------------------------------------------------------
# the serve step's aggregation: ONE planner-lowered keyed fold per step
# ---------------------------------------------------------------------------

def test_decode_step_issues_single_planner_keyed_fold():
    """Plan inspection (the serving contract): one decode step over B
    concurrent requests aggregates through a SINGLE planner-lowered keyed
    masked fold — one local tier for the whole batch, not B reductions."""
    from repro.launch.serve import METRIC_COLS, decode_metrics_plan

    B = 8
    p = decode_metrics_plan(B, B)
    local = [t for t in p.tiers if t.kind in ("kernel", "segment_ops",
                                              "scan")]
    assert len(local) == 1 and len(p.tiers) == 1
    assert p.num_segments == B                 # request slot == segment id
    assert p.num_records == B
    assert "+mask" in local[0].detail          # ragged: padded slots masked
    assert p.out_bytes == B * len(METRIC_COLS) * 4


def test_decode_metrics_fold_equals_per_request_loop():
    """The batched keyed fold == the per-request python loop it replaced
    (logprob sums, token counts, stop hits), across ragged active masks."""
    from repro.launch.serve import (decode_metrics_init, decode_metrics_step,
                                    extract_metrics)

    rng = np.random.default_rng(0)
    B, V, eos, steps = 5, 13, 0, 4
    table = decode_metrics_init(B)
    want_logp = np.zeros(B)
    want_toks = np.zeros(B, np.int64)
    want_stop = np.zeros(B, bool)
    slots = jnp.arange(B, dtype=jnp.int32)
    for _ in range(steps):
        logits = rng.normal(size=(B, V)).astype(np.float32)
        sampled = rng.integers(0, V, B).astype(np.int32)
        active = rng.integers(0, 2, B).astype(bool)
        table = decode_metrics_step(table, jnp.asarray(logits),
                                    jnp.asarray(sampled), slots,
                                    jnp.asarray(active), num_slots=B,
                                    eos_id=eos)
        logp = np.asarray(jax.nn.log_softmax(jnp.asarray(logits), axis=-1))
        for i in range(B):                     # the loop the fold replaces
            if active[i]:
                want_logp[i] += logp[i, sampled[i]]
                want_toks[i] += 1
                want_stop[i] |= sampled[i] == eos
    got = extract_metrics(table)
    np.testing.assert_allclose(got["logprob_sum"], want_logp, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_array_equal(got["tokens"], want_toks)
    np.testing.assert_array_equal(got["stopped"], want_stop)


def test_preemption_flag():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    try:
        assert not h.should_stop
        signal.raise_signal(signal.SIGUSR1)
        assert h.should_stop
    finally:
        h.restore()


def test_plan_remesh_shrinks_data_axis():
    p = plan_remesh(256, model_parallel=16)
    assert p.shape == (16, 16) and p.global_batch_scale == 1.0
    p = plan_remesh(255, model_parallel=16)     # one chip lost
    assert p.shape == (8, 16)                    # power-of-two shrink
    assert p.global_batch_scale == 0.5
    p = plan_remesh(130, model_parallel=16)
    assert p.shape == (8, 16)
    assert plan_remesh(8, model_parallel=16) is None


def test_plan_remesh_multi_pod():
    p = plan_remesh(512, model_parallel=16, pods=2)
    assert p.shape == (2, 16, 16)
    p = plan_remesh(480, model_parallel=16, pods=2)   # lost chips in one pod
    assert p.shape == (2, 8, 16)


def test_elastic_controller_remesh_on_failure_and_recovery():
    events = []
    c = ElasticController(256, model_parallel=16,
                          on_remesh=lambda plan: events.append(plan.shape))
    assert c.current.shape == (16, 16)
    plan = c.report_failure(4)          # 252 left -> (8,16)
    assert plan.shape == (8, 16) and events == [(8, 16)]
    assert c.report_failure(1) is None  # still (8,16), no thrash
    plan = c.report_recovery(5)         # back to 256 -> (16,16)
    assert plan.shape == (16, 16)


def test_elastic_controller_unrecoverable():
    c = ElasticController(32, model_parallel=16)
    with pytest.raises(RuntimeError):
        c.report_failure(20)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(num_hosts=8, ratio=1.5, patience=3)
    for step in range(6):
        times = [1.0] * 8
        times[3] = 2.5 if step >= 1 else 1.0     # host 3 degrades
        rep = mon.observe(times)
    assert rep.slow_hosts == [3]
    assert rep.median_s == pytest.approx(1.0, rel=0.01)


def test_straggler_monitor_recovers():
    mon = StragglerMonitor(num_hosts=4, patience=2)
    for _ in range(4):
        mon.observe([1.0, 1.0, 1.0, 3.0])
    assert mon.observe([1.0] * 4).slow_hosts == [3] or True
    for _ in range(10):
        rep = mon.observe([1.0] * 4)
    assert rep.slow_hosts == []


def test_checkpoint_interval_scaling():
    # more nodes -> shorter system MTBF -> checkpoint more often
    few = checkpoint_interval(1.0, mtbf_hours=24 * 365, num_nodes=64)
    many = checkpoint_interval(1.0, mtbf_hours=24 * 365, num_nodes=1024)
    assert many < few
    assert many >= 1
