"""Fault-tolerance control plane (simulated signals/timings)."""
import signal

import pytest

from repro.runtime import (ElasticController, PreemptionHandler,
                           StragglerMonitor, checkpoint_interval, plan_remesh)


def test_preemption_flag():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    try:
        assert not h.should_stop
        signal.raise_signal(signal.SIGUSR1)
        assert h.should_stop
    finally:
        h.restore()


def test_plan_remesh_shrinks_data_axis():
    p = plan_remesh(256, model_parallel=16)
    assert p.shape == (16, 16) and p.global_batch_scale == 1.0
    p = plan_remesh(255, model_parallel=16)     # one chip lost
    assert p.shape == (8, 16)                    # power-of-two shrink
    assert p.global_batch_scale == 0.5
    p = plan_remesh(130, model_parallel=16)
    assert p.shape == (8, 16)
    assert plan_remesh(8, model_parallel=16) is None


def test_plan_remesh_multi_pod():
    p = plan_remesh(512, model_parallel=16, pods=2)
    assert p.shape == (2, 16, 16)
    p = plan_remesh(480, model_parallel=16, pods=2)   # lost chips in one pod
    assert p.shape == (2, 8, 16)


def test_elastic_controller_remesh_on_failure_and_recovery():
    events = []
    c = ElasticController(256, model_parallel=16,
                          on_remesh=lambda plan: events.append(plan.shape))
    assert c.current.shape == (16, 16)
    plan = c.report_failure(4)          # 252 left -> (8,16)
    assert plan.shape == (8, 16) and events == [(8, 16)]
    assert c.report_failure(1) is None  # still (8,16), no thrash
    plan = c.report_recovery(5)         # back to 256 -> (16,16)
    assert plan.shape == (16, 16)


def test_elastic_controller_unrecoverable():
    c = ElasticController(32, model_parallel=16)
    with pytest.raises(RuntimeError):
        c.report_failure(20)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(num_hosts=8, ratio=1.5, patience=3)
    for step in range(6):
        times = [1.0] * 8
        times[3] = 2.5 if step >= 1 else 1.0     # host 3 degrades
        rep = mon.observe(times)
    assert rep.slow_hosts == [3]
    assert rep.median_s == pytest.approx(1.0, rel=0.01)


def test_straggler_monitor_recovers():
    mon = StragglerMonitor(num_hosts=4, patience=2)
    for _ in range(4):
        mon.observe([1.0, 1.0, 1.0, 3.0])
    assert mon.observe([1.0] * 4).slow_hosts == [3] or True
    for _ in range(10):
        rep = mon.observe([1.0] * 4)
    assert rep.slow_hosts == []


def test_checkpoint_interval_scaling():
    # more nodes -> shorter system MTBF -> checkpoint more often
    few = checkpoint_interval(1.0, mtbf_hours=24 * 365, num_nodes=64)
    many = checkpoint_interval(1.0, mtbf_hours=24 * 365, num_nodes=1024)
    assert many < few
    assert many >= 1
