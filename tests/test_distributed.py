"""Multi-device distribution tests, run in subprocesses with 8 fake host
devices (the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_distributed(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import repro  # noqa: F401  (installs the jax API compat shims first)
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
mesh2 = jax.make_mesh((4, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
"""


def test_sharded_mapreduce_strategies():
    run_distributed(PRELUDE + """
from repro.core import average_by_key_job
rng = np.random.default_rng(0)
keys = rng.integers(0, 16, 128); vals = rng.normal(size=128).astype(np.float32)
records = {"key": jnp.asarray(keys), "value": jnp.asarray(vals)}
job = average_by_key_job(16)
oracle = np.array([vals[keys==k].mean() if (keys==k).any() else 0.0 for k in range(16)])
for strat in ("naive", "combiner", "in_mapper"):
    out = np.asarray(job.run_sharded(records, mesh, strategy=strat))
    assert np.allclose(out, oracle, atol=1e-5), (strat, out, oracle)
print("ok")
""")


def test_sharded_mapreduce_executes_plan_shuffle():
    """run_sharded consumes the PLAN's shuffle choice (no selection of its
    own): divisible key counts still lower to reduce-scatter (the pre-plan
    special case, now a cost-model decision), non-divisible to allreduce —
    and both give the oracle answer on a real 8-device mesh."""
    run_distributed(PRELUDE + """
from repro.core import average_by_key_job
rng = np.random.default_rng(4)
for num_keys in (16, 13):    # 16 % 8 == 0 -> reduce_scatter; 13 -> allreduce
    keys = rng.integers(0, num_keys, 128)
    vals = rng.normal(size=128).astype(np.float32)
    records = {"key": jnp.asarray(keys), "value": jnp.asarray(vals)}
    job = average_by_key_job(num_keys)
    plan = job.plan(records, strategy="combiner", num_shards=8,
                    axis_name="data")
    want_algo = "reduce_scatter" if num_keys % 8 == 0 else "allreduce"
    assert plan.shuffle_algorithm == want_algo, (num_keys, plan.describe())
    stats = job.stats(records, strategy="combiner", num_shards=8)
    assert stats.shuffle_algorithm == want_algo
    assert stats.predicted_us > 0
    oracle = np.array([vals[keys==k].mean() if (keys==k).any() else 0.0
                       for k in range(num_keys)])
    for strat in ("combiner", "in_mapper"):
        out = np.asarray(job.run_sharded(records, mesh, strategy=strat))
        assert np.allclose(out, oracle, atol=1e-5), (num_keys, strat)
print("ok")
""")


def test_combine_keyed_table_both_algorithms():
    """combine_keyed_table('reduce_scatter') == combine_keyed_table(
    'allreduce') == the replicated sum, inside a real shard_map."""
    run_distributed(PRELUDE + """
from repro.core import monoids
from repro.dist.collectives import combine_keyed_table
rng = np.random.default_rng(5)
table = jnp.asarray(rng.normal(size=(8, 16, 3)).astype(np.float32))
want = np.asarray(table).sum(0)
spec = jax.sharding.PartitionSpec("data")
for algo in ("allreduce", "reduce_scatter"):
    fn = jax.shard_map(
        lambda t, algo=algo: combine_keyed_table(monoids.sum_, t[0], "data",
                                                 algorithm=algo),
        mesh=mesh, in_specs=(spec,),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)
    out = np.asarray(fn(table))       # per-device slice (1, 16, 3) -> t[0]
    assert np.allclose(out, want, atol=1e-5), algo
print("ok")
""")


def test_hierarchical_psum_equals_flat():
    run_distributed(PRELUDE + """
from repro.core.aggregation import hierarchical_psum
from functools import partial
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
spec = jax.sharding.PartitionSpec("data")

def flat(v):
    return jax.lax.psum(v, ("data", "model"))

def hier(v):
    return hierarchical_psum(v, ici_axis="model", dcn_axis="data")

f1 = jax.shard_map(flat, mesh=mesh2, in_specs=spec, out_specs=spec, check_vma=False)
f2 = jax.shard_map(hier, mesh=mesh2, in_specs=spec, out_specs=spec, check_vma=False)
np.testing.assert_allclose(np.asarray(f1(x)), np.asarray(f2(x)), rtol=1e-6)
print("ok")
""")


def test_monoid_allreduce_attn_state():
    """Distributed flash-decoding merge == single-device softmax."""
    run_distributed(PRELUDE + """
from repro.core import monoids
from repro.core.aggregation import monoid_allreduce
rng = np.random.default_rng(1)
S, d = 64, 4                      # KV length sharded 8 ways
logits = jnp.asarray(rng.normal(size=(S,)).astype(np.float32))
v = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
w = jax.nn.softmax(logits)
want = w @ v

def shard_fn(lg, vv):
    m = jnp.max(lg)
    e = jnp.exp(lg - m)
    state = (m, e.sum(), e @ vv)
    state = monoid_allreduce(monoids.attn_state, state, "data")
    return monoids.attn_state.extract(state)

spec = jax.sharding.PartitionSpec("data")
out = jax.shard_map(shard_fn, mesh=mesh, in_specs=(spec, spec),
                    out_specs=jax.sharding.PartitionSpec(), check_vma=False)(logits, v)
np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
print("ok")
""")


def test_execute_fold_mesh_tier_hierarchical():
    """The planner's collective tier on an 8-device (data x pod) mesh: a
    keyed fold per shard, then ICI-first-then-DCN combine — one entry point,
    same answer as the global fold."""
    run_distributed(PRELUDE + """
from repro.core import execute_fold, monoids
mesh_pod = jax.make_mesh((4, 2), ("data", "pod"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(5)
n, keys = 128, 8
vals = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
segs = jnp.asarray(rng.integers(0, keys, n).astype(np.int32))
want = jax.ops.segment_sum(vals, segs, num_segments=keys)

def body(v, k):
    return execute_fold(monoids.sum_, v, segment_ids=k, num_segments=keys,
                        mesh_axes=("pod", "data"))

spec = jax.sharding.PartitionSpec(("data", "pod"))
out = jax.shard_map(body, mesh=mesh_pod, in_specs=(spec, spec),
                    out_specs=jax.sharding.PartitionSpec(),
                    check_vma=False)(vals, segs)
np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                           atol=1e-4)
print("ok")
""")


def test_execute_fold_mesh_tier_ragged_valid_mask():
    """The serving case at mesh scale: a RAGGED keyed fold (per-shard
    valid_mask) through the planner's collective tier == the dense fold
    over only the valid rows — padding never crosses the wire combined in."""
    run_distributed(PRELUDE + """
from repro.core import execute_fold, monoids
mesh_pod = jax.make_mesh((4, 2), ("data", "pod"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(11)
n, keys = 128, 8
vals = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
segs = jnp.asarray(rng.integers(0, keys, n).astype(np.int32))
mask = jnp.asarray(rng.random(n) < 0.6)
want = jax.ops.segment_sum(vals[mask], segs[mask], num_segments=keys)

def body(v, k, mk):
    return execute_fold(monoids.sum_, v, segment_ids=k, num_segments=keys,
                        valid_mask=mk, mesh_axes=("pod", "data"))

spec = jax.sharding.PartitionSpec(("data", "pod"))
out = jax.shard_map(body, mesh=mesh_pod, in_specs=(spec, spec, spec),
                    out_specs=jax.sharding.PartitionSpec(),
                    check_vma=False)(vals, segs, mask)
np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                           atol=1e-4)
print("ok")
""")


def test_moe_replicated_matches_local():
    run_distributed(PRELUDE + """
import dataclasses
from repro.configs import get_config
from repro.models import init_params
from repro.models import moe as M
cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b", smoke=True), dtype=jnp.float32)
params, _ = init_params(cfg, jax.random.PRNGKey(0))
ffn = jax.tree_util.tree_map(lambda p: p[0], params["layers"])["slot_0"]["ffn"]
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
ref, stats_ref = M.moe_ffn_local(ffn, cfg, x)
out, stats = M.moe_ffn_replicated(ffn, cfg, x, mesh2, axis_name="model",
                                  batch_axes=("data",))
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)
np.testing.assert_array_equal(np.asarray(stats["expert_load"]),
                              np.asarray(stats_ref["expert_load"]))
print("ok")
""")


def test_moe_a2a_matches_local():
    run_distributed(PRELUDE + """
import dataclasses
from repro.configs import get_config
from repro.models import init_params
from repro.models import moe as M
cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b", smoke=True),
                          dtype=jnp.float32, moe_capacity_factor=8.0)
params, _ = init_params(cfg, jax.random.PRNGKey(0))
ffn = jax.tree_util.tree_map(lambda p: p[0], params["layers"])["slot_0"]["ffn"]
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
ref, _ = M.moe_ffn_local(ffn, cfg, x)
out, stats = M.moe_ffn_a2a(ffn, cfg, x, mesh2, axis_name="model",
                            batch_axes=("data",))
assert int(stats["dropped"]) == 0, int(stats["dropped"])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)
print("ok")
""")


def test_flash_decode_shardmap_matches_dense():
    run_distributed(PRELUDE + """
import dataclasses
from repro.configs import get_config
from repro.models import init_params, ParamBuilder
from repro.models import attention as A
cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True), dtype=jnp.float32)
pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
A.init_attn(pb, cfg)
p = pb.params
B, S = 2, 64
x = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.num_kv_heads, cfg.head_dim), jnp.float32)
v = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.num_kv_heads, cfg.head_dim), jnp.float32)
pos = jnp.int32(40)
want, (k1, v1) = A.attention_decode(p, cfg, x, (k, v), pos)
got, (k2, v2) = A.flash_decode_shardmap(p, cfg, x, (k, v), pos, mesh,
                                        axis_name="data")
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
np.testing.assert_allclose(np.asarray(k2), np.asarray(k1), rtol=1e-5)
print("ok")
""")


def test_ring_attention_matches_dense():
    """Ring attention (collective_permute hops folding AttnState) == dense
    causal softmax attention, on an 8-device ring."""
    run_distributed(PRELUDE + """
from repro.models.attention import ring_attention_shardmap
from repro.kernels import ref
rng = np.random.default_rng(3)
B, S, H, d = 2, 64, 4, 16
q = jnp.asarray(rng.normal(size=(B, S, H, d)).astype(np.float32))
k = jnp.asarray(rng.normal(size=(B, S, H, d)).astype(np.float32))
v = jnp.asarray(rng.normal(size=(B, S, H, d)).astype(np.float32))
got = ring_attention_shardmap(q, k, v, mesh, axis_name="data")
want = ref.flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3))
np.testing.assert_allclose(np.asarray(got), np.asarray(want.transpose(0, 2, 1, 3)),
                           rtol=2e-4, atol=2e-4)
print("ok")
""")


def test_sharding_rules_1_device():
    """trim_rules / spec_for / param_shardings / act on a 1-device mesh (the
    main test process): everything degrades to replication, and act() is a
    no-op outside any use_rules scope."""
    import jax
    from jax.sharding import PartitionSpec as P
    import jax.numpy as jnp
    from repro.dist import sharding as shd

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = shd.trim_rules(shd.TRAIN_RULES, mesh)
    assert rules["batch"] == "data"          # 'pod' dropped: not in this mesh
    assert rules["mlp"] == "model"
    assert rules["embed"] is None

    # divisibility: a dim of 3 can't shard over nothing on 1 device anyway,
    # but the spec machinery must emit clean specs with trailing Nones cut
    assert shd.spec_for(("batch", "seq", "mlp"), rules, mesh,
                        shape=(4, 16, 8)) == P("data", None, "model")
    assert shd.spec_for(("embed",), rules, mesh, shape=(8,)) == P()

    # act() outside use_rules: identity
    x = jnp.ones((2, 3))
    assert shd.act(x, ("batch", "embed")) is x

    # act() inside use_rules: applies a constraint without changing values
    with shd.use_rules(mesh, shd.TRAIN_RULES):
        y = jax.jit(lambda v: shd.act(v, ("batch", "embed")))(x)
    assert jnp.allclose(y, x)

    # param_shardings over a real config's param tree
    from repro.configs import get_config
    from repro.models import param_axes, param_shapes
    cfg = get_config("qwen3-0.6b", smoke=True)
    shard = shd.param_shardings(param_shapes(cfg), param_axes(cfg), mesh, rules)
    leaves = jax.tree_util.tree_leaves(shard)
    assert leaves and all(
        isinstance(s, jax.sharding.NamedSharding) for s in leaves)


def test_sharding_rules_8_devices():
    """Rule tables on a 4x2 mesh: dedupe (expert vs mlp both -> 'model'),
    divisibility fallback, and that act() inside a jitted use_rules scope
    actually shards the output."""
    run_distributed(PRELUDE + """
from jax.sharding import PartitionSpec as P
from repro.dist import sharding as shd

rules = shd.trim_rules(shd.TRAIN_RULES, mesh2)
# first logical dim wins the 'model' axis; the duplicate is dropped
assert shd.spec_for(("expert", "embed", "mlp"), rules, mesh2,
                    shape=(8, 16, 32)) == P("model")
# divisibility: batch=3 not divisible by data=4 -> replicated
assert shd.spec_for(("batch", "seq"), rules, mesh2, shape=(3, 16)) == P()
assert shd.spec_for(("batch", "seq"), rules, mesh2, shape=(8, 16)) == P("data")

x = jnp.zeros((8, 16, 64))
with shd.use_rules(mesh2, shd.TRAIN_RULES):
    y = jax.jit(lambda v: shd.act(v, ("batch", "seq", "mlp")))(x)
spec = y.sharding.spec
assert tuple(spec) in ((("data",), None, ("model",)), ("data", None, "model")), spec

# multi-pod table: batch spans pod x data when the mesh has a pod axis
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*3)
r3 = shd.trim_rules(shd.TRAIN_RULES, mesh3)
assert shd.spec_for(("batch", "seq"), r3, mesh3, shape=(8, 16)) == P(("pod", "data"))
print("ok")
""")


def test_collectives_cross_dcn_once():
    """dist.collectives on a (pod, data, model) mesh: the hierarchical
    monoid reductions equal flat collectives, with the DCN ('pod') axis
    crossed on pre-combined values."""
    run_distributed(PRELUDE + """
from repro.core import monoids
from repro.dist import collectives as col
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*3)
assert col.dcn_axes(mesh3) == ("pod",)
assert col.ici_axes(mesh3) == ("data", "model")

x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
spec = jax.sharding.PartitionSpec("data")

def flat(v):
    return jax.lax.psum(v, ("pod", "data", "model"))

def hier_grad(v):
    return col.grad_sync(v, mesh3)

def hier_metrics(v):
    return col.metrics_sync(v, mesh3)

def hier_max(v):
    return col.cross_mesh_allreduce(monoids.max_, v, mesh3)

kw = dict(mesh=mesh3, in_specs=spec, out_specs=spec, check_vma=False)
want = np.asarray(jax.shard_map(flat, **kw)(x))
for fn in (hier_grad, hier_metrics):
    got = np.asarray(jax.shard_map(fn, **kw)(x))
    np.testing.assert_allclose(got, want, rtol=1e-6)

def flat_max(v):
    return jax.lax.pmax(v, ("pod", "data", "model"))

np.testing.assert_allclose(np.asarray(jax.shard_map(hier_max, **kw)(x)),
                           np.asarray(jax.shard_map(flat_max, **kw)(x)))
print("ok")
""")


def test_train_step_multi_device_matches_single():
    """2-device DP x 2-device TP training step == single-device step."""
    run_distributed(PRELUDE + """
import dataclasses
from repro.configs import get_config, ShapeCell
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import init_opt_state
cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True), dtype=jnp.float32)
shape = ShapeCell("t", "train", 32, 4)
mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
toks = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
outs = {}
for name, m in (("single", mesh1), ("dist", mesh2)):
    built = make_train_step(cfg, m, shape, donate=False)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, built.in_shardings[0])
    opt = jax.device_put(init_opt_state(params), built.in_shardings[1])
    _, _, metrics = built.fn(params, opt, batch)
    outs[name] = {k: float(v) for k, v in metrics.items()}
for k in ("loss", "grad_norm"):
    a, b = outs["single"][k], outs["dist"][k]
    assert abs(a - b) / max(abs(a), 1e-6) < 5e-3, (k, a, b)
print("ok", outs["dist"]["loss"])
""")


def test_async_microbatch_fold_equals_sync_dense():
    """The double-buffered async tier re-brackets, never re-weighs: forced
    async == forced sync == auto on a real (2 pod x 4 ici) mesh."""
    run_distributed(PRELUDE + """
from repro.core import execute_fold, monoids
mesh_ov = jax.make_mesh((2, 4), ("pod", "x"),
                        axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(21)
data = jnp.asarray(rng.normal(size=(8, 4, 16)).astype(np.float32))
want = np.asarray(data).sum((0, 1))
spec = jax.sharding.PartitionSpec(("pod", "x"))

def run(layout):
    body = lambda v: execute_fold(monoids.sum_, v[0], mesh_axes=("x", "pod"),
                                  layout=layout)
    return np.asarray(jax.shard_map(
        body, mesh=mesh_ov, in_specs=(spec,),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)(data))

for layout in ("scan", "async", "auto"):
    np.testing.assert_allclose(run(layout), want, rtol=1e-4, atol=1e-4)
print("ok")
""")


def test_lossy_fold_ef_invariant_at_mesh_scale():
    """Sync and async lossy crossings on the (pod, x) mesh: the folded
    output plus the per-pod error-feedback residuals equals the dense sum —
    compression loses nothing, it only defers."""
    run_distributed(PRELUDE + """
from repro.core import execute_fold, monoids
mesh_ov = jax.make_mesh((2, 4), ("pod", "x"),
                        axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(22)
data = jnp.asarray(rng.normal(size=(8, 4, 16)).astype(np.float32))
want = np.asarray(data).sum((0, 1))
spec = jax.sharding.PartitionSpec(("pod", "x"))

def run(layout, lossy):
    def body(v):
        out, ef = execute_fold(monoids.sum_, v[0], mesh_axes=("x", "pod"),
                               layout=layout, lossy=lossy)
        return out + jax.lax.psum(ef, "pod")
    return np.asarray(jax.shard_map(
        body, mesh=mesh_ov, in_specs=(spec,),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)(data))

for layout in ("scan", "async"):
    for lossy in ("topk:0.25", "int8"):
        got = run(layout, lossy)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{layout}/{lossy}")
print("ok")
""")
