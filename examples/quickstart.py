"""Quickstart: the paper in 80 lines.

1. Monoidify a non-associative aggregation (mean) -> combiners become legal.
2. Run the paper's Algorithms 1/3/4 on a MapReduce job and print the
   shuffle-byte reduction.
3. See Algorithm 2 get rejected by the combiner contract.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (MonoidTypeError, STRATEGIES, algorithm2_combiner,
                        average_by_key_job, monoids, validate_combiner)

# -- 1. the (sum, count) monoid — the paper's running example ---------------
mean = monoids.mean
a = mean.lift(jnp.float32(1.0))            # (1.0, 1)
b = mean.combine(mean.lift(jnp.float32(2.0)),
                 mean.combine(mean.lift(jnp.float32(3.0)),
                              mean.lift(jnp.float32(4.0))))
print("Avg(1,2,3,4) via any bracketing:", float(mean.extract(mean.combine(a, b))))
# naive mean-of-means is WRONG — the motivating inequality:
print("Avg(Avg(1,2), Avg(3,4,5)) =", (1.5 + 4.0) / 2,
      "!= Avg(1..5) =", 3.0)

# -- 2. mean-by-key with all three strategies --------------------------------
rng = np.random.default_rng(0)
records = {"key": jnp.asarray(rng.integers(0, 8, 4096).astype(np.int32)),
           "value": jnp.asarray(rng.normal(size=4096).astype(np.float32))}
job = average_by_key_job(num_keys=8)
print(f"\n{'strategy':12s} {'intermediate':>12s} {'shuffle bytes':>14s} {'reduction':>10s}")
for strat in STRATEGIES:
    out = job.run_local(records, strategy=strat, num_shards=8)
    st = job.stats(records, strategy=strat, num_shards=8)
    print(f"{strat:12s} {st.intermediate_values:12d} "
          f"{st.shuffle_bytes_mapreduce:14d} {st.reduction_vs_naive():9.1f}x")
print("all strategies agree:", np.asarray(out)[:3], "...")

# -- 3. Algorithm 2 is rejected ----------------------------------------------
try:
    validate_combiner(job.monoid, jnp.float32(1.0), algorithm2_combiner)
except MonoidTypeError as e:
    print("\nAlgorithm 2 rejected by the combiner contract:\n ", str(e)[:100])

# -- bonus: the same idea inside the LM stack --------------------------------
# the attention softmax state is a monoid too (flash attention / decoding):
s1 = (jnp.float32(0.5), jnp.float32(2.0), jnp.ones((4,)))
s2 = (jnp.float32(1.5), jnp.float32(1.0), 2 * jnp.ones((4,)))
merged = monoids.attn_state.combine(s1, s2)
print("\nattn_state combine (m, l, o):", [np.asarray(x) for x in merged[:2]])
print("=> chunked attention, flash-decoding and ring attention are all "
      "re-bracketings of this combine.")
