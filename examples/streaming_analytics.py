"""Summingbird in miniature (paper §4): ONE monoid state serves both the
low-latency streaming path (fold batch-by-batch as data arrives) and the
batch path (tree-reduce over the whole corpus at once) — and a third path,
the sharded MapReduce engine — all three agree exactly.  The windowed
section then runs the same algebra over an *infinite* stream: two-stacks
sliding windows, decay monoids, and per-user sessions whose folds lower
through the execution planner (session id == segment id).

Run:  PYTHONPATH=src python examples/streaming_analytics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids, tree_fold, word_count_job
from repro.data import (DataConfig, SlidingWindow, SyntheticCorpus,
                        TumblingWindow, init_stats, make_stream_stats,
                        session_fold, sessionize, summarize, tumbling_fold,
                        update_stats)

VOCAB = 2_000
corpus = SyntheticCorpus(DataConfig(vocab_size=VOCAB, seq_len=256,
                                    global_batch=8, seed=7))
batches = [corpus(i)["tokens"] for i in range(8)]
all_tokens = jnp.concatenate([b.reshape(-1) for b in batches])

# -- path 1: STREAMING — in-mapper combining, one batch at a time ------------
m = make_stream_stats()
state = init_stats(m)
for b in batches:
    state = update_stats(state, b)           # O(1) state, any arrival order
stream = summarize(m, state)

# -- path 2: BATCH — the same monoid, tree-reduced over per-batch states -----
per_batch = [update_stats(init_stats(m), b) for b in batches]
stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_batch)
batch_state = tree_fold(m, stacked)
batch = summarize(m, batch_state)

print("same monoid, two execution plans (the Summingbird property):")
print(f"  streaming: tokens={stream['tokens']}, distinct~{stream['approx_distinct']:.0f}")
print(f"  batch    : tokens={batch['tokens']}, distinct~{batch['approx_distinct']:.0f}")
assert stream["tokens"] == batch["tokens"]
assert np.array_equal(np.asarray(state["cms"]), np.asarray(batch_state["cms"]))
print("  CMS/HLL/Bloom states identical: True")

# -- path 3: the MapReduce engine on the same query ---------------------------
job = word_count_job(VOCAB)
counts = job.run_local(all_tokens, strategy="in_mapper", num_shards=8)
top = np.argsort(np.asarray(counts))[::-1][:5]
print("\ntop-5 tokens by exact MapReduce word count:", top.tolist())
for t in top[:3]:
    est = int(monoids.cms_query(state["cms"], jnp.int32(int(t))))
    print(f"  token {t}: exact={int(counts[t])}, cms_estimate={est} (>= exact)")

true_distinct = len(np.unique(np.asarray(all_tokens)))
err = abs(stream["approx_distinct"] - true_distinct) / true_distinct
print(f"\nHLL distinct estimate error: {100*err:.1f}% "
      f"(true {true_distinct}, est {stream['approx_distinct']:.0f})")

# -- path 4: WINDOWED — the same monoids over an infinite event stream --------
# a synthetic per-user event stream: (user, timestamp, value)
rng = np.random.default_rng(11)
N_EVENTS, N_USERS = 400, 6
users = rng.integers(0, N_USERS, N_EVENTS)
ts = np.cumsum(rng.uniform(0.0, 0.4, N_EVENTS))
vals = rng.integers(1, 20, N_EVENTS).astype(np.float32)

# sliding window: last-32-events sum via the two-stacks trick — O(1)
# amortized combines per event, no inverse needed (works for max/CMS/HLL)
win = SlidingWindow(monoids.sum_, 32)
for v in vals:
    win.push(jnp.asarray(v))
brute = float(vals[-32:].sum())
print(f"\nsliding window (two-stacks, w=32): sum={float(np.asarray(win.extract())):.0f} "
      f"== brute force {brute:.0f}; "
      f"{win.flip_combines / win.pushes:.2f} flip combines/event")
assert float(np.asarray(win.extract())) == brute

# tumbling windows: window id == segment id, ONE planner-lowered keyed fold
n_windows = int(ts[-1] // 10.0) + 1
table = np.asarray(tumbling_fold(monoids.sum_, jnp.asarray(vals), ts,
                                 width=10.0, num_windows=n_windows))
print(f"tumbling windows (width 10s, one keyed fold): "
      f"{[f'{x:.0f}' for x in table]}")
assert np.isclose(table.sum(), vals.sum())

# decay monoid: exponentially time-decayed per-user activity score
half_life = 20.0
dm = monoids.decayed_sum(half_life)
score = {}
for u, v, t in zip(users, vals, ts):
    s = dm.lift((float(v), float(t)))
    score[u] = s if u not in score else dm.combine(score[u], s)
now = float(ts[-1])
top_user = max(score, key=lambda u: float(monoids.decayed_value(score[u], now, half_life)))
print(f"decayed activity (half-life {half_life:.0f}s): hottest user = {top_user} "
      f"(score {float(monoids.decayed_value(score[top_user], now, half_life)):.1f})")

# sessionization: session id == segment id -> per-session planner fold
sids, n_sessions = sessionize(users, ts, gap=1.0)
per_session = np.asarray(session_fold(monoids.sum_, jnp.asarray(vals),
                                      sids, n_sessions))
print(f"sessionized {N_EVENTS} events from {N_USERS} users into "
      f"{n_sessions} sessions (gap 1s); "
      f"largest session sum={per_session.max():.0f}")
assert np.isclose(per_session.sum(), vals.sum())
