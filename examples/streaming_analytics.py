"""Summingbird in miniature (paper §4): ONE monoid state serves both the
low-latency streaming path (fold batch-by-batch as data arrives) and the
batch path (tree-reduce over the whole corpus at once) — and a third path,
the sharded MapReduce engine — all three agree exactly.

Run:  PYTHONPATH=src python examples/streaming_analytics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids, tree_fold, word_count_job
from repro.data import (DataConfig, SyntheticCorpus, init_stats,
                        make_stream_stats, summarize, update_stats)

VOCAB = 2_000
corpus = SyntheticCorpus(DataConfig(vocab_size=VOCAB, seq_len=256,
                                    global_batch=8, seed=7))
batches = [corpus(i)["tokens"] for i in range(8)]
all_tokens = jnp.concatenate([b.reshape(-1) for b in batches])

# -- path 1: STREAMING — in-mapper combining, one batch at a time ------------
m = make_stream_stats()
state = init_stats(m)
for b in batches:
    state = update_stats(state, b)           # O(1) state, any arrival order
stream = summarize(m, state)

# -- path 2: BATCH — the same monoid, tree-reduced over per-batch states -----
per_batch = [update_stats(init_stats(m), b) for b in batches]
stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_batch)
batch_state = tree_fold(m, stacked)
batch = summarize(m, batch_state)

print("same monoid, two execution plans (the Summingbird property):")
print(f"  streaming: tokens={stream['tokens']}, distinct~{stream['approx_distinct']:.0f}")
print(f"  batch    : tokens={batch['tokens']}, distinct~{batch['approx_distinct']:.0f}")
assert stream["tokens"] == batch["tokens"]
assert np.array_equal(np.asarray(state["cms"]), np.asarray(batch_state["cms"]))
print("  CMS/HLL/Bloom states identical: True")

# -- path 3: the MapReduce engine on the same query ---------------------------
job = word_count_job(VOCAB)
counts = job.run_local(all_tokens, strategy="in_mapper", num_shards=8)
top = np.argsort(np.asarray(counts))[::-1][:5]
print("\ntop-5 tokens by exact MapReduce word count:", top.tolist())
for t in top[:3]:
    est = int(monoids.cms_query(state["cms"], jnp.int32(int(t))))
    print(f"  token {t}: exact={int(counts[t])}, cms_estimate={est} (>= exact)")

true_distinct = len(np.unique(np.asarray(all_tokens)))
err = abs(stream["approx_distinct"] - true_distinct) / true_distinct
print(f"\nHLL distinct estimate error: {100*err:.1f}% "
      f"(true {true_distinct}, est {stream['approx_distinct']:.0f})")
