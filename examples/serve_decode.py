"""Continuous-batching serving through the public `repro.serving` facade:
requests with ragged prompts roll through a fixed population of slots, a
freed slot is handed to the next waiting request mid-decode, and tokens
stream back per-request as they decode.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-0.6b]
"""
import argparse

import numpy as np

from repro.serving import ServeConfig, build_engine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--requests", type=int, default=6)
ap.add_argument("--slots", type=int, default=2)
ap.add_argument("--gen", type=int, default=8)
ap.add_argument("--temperature", type=float, default=1.0)
args = ap.parse_args()

config = ServeConfig(arch=args.arch, num_slots=args.slots,
                     prefill_buckets=(8, 16), max_new_tokens=args.gen,
                     temperature=args.temperature)
engine = build_engine(config)

rng = np.random.default_rng(0)
vocab = engine.backend.vocab_size
uids = []
for _ in range(args.requests):
    plen = int(rng.integers(4, config.max_prompt + 1))
    uids.append(engine.submit(rng.integers(1, vocab, plen).tolist()))

print(f"arch={args.arch}  slots={args.slots}  requests={args.requests}  "
      f"buckets={config.prefill_buckets}  gen<={args.gen}")

# stream: every token event carries (uid, slot, index); "cache" reports the
# admission's prefix-cache hit; "done" carries the final per-request metrics
# folded by the engine's keyed masked fold
streamed = {u: [] for u in uids}
for event in engine.run():
    if event.kind == "token":
        streamed[event.uid].append(event.token)
        if event.index == 0:
            print(f"  uid={event.uid} first token on slot {event.slot} "
                  f"(ttft {event.ttft_s * 1e3:.0f}ms)")
    elif event.kind == "cache" and event.hit_tokens:
        print(f"  uid={event.uid} prefix hit: {event.hit_tokens}/"
              f"{event.prompt_tokens} prompt tokens from the trie "
              f"({event.bytes_saved} KV bytes not re-prefilled)")
    elif event.kind == "done":
        r = event.result
        print(f"  uid={r.uid} done: {len(r.tokens)} tokens, "
              f"logprob_sum={r.logprob_sum:.2f}, "
              f"{'eos' if r.stopped else 'budget'} stop")

st = engine.stats
assert all(streamed[u] == engine.result(u).tokens for u in uids)
print(f"served {st.completed} requests / {st.generated_tokens} tokens in "
      f"{st.steps} rolling decode steps, {st.slot_reuses} slot reuses")
if engine.prefix is not None:
    ps = engine.prefix.stats
    print(f"prefix cache: {engine.prefix.node_count} nodes, "
          f"hit_rate={ps.hit_rate():.0%}, {ps.bytes_saved} bytes saved")
print(f"compiled shapes: {engine.compile_counts()} "
      f"(bound: {engine.compile_bound()})")
