"""Batched serving: prefill a prompt batch, then decode with per-layer KV
caches — the decode step is the same `serve_step` the 256-chip dry-run
lowers; here it runs on CPU with a smoke config.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-0.6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import context_spec, get_config
from repro.models import decode_step, init_cache, init_params

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=48)
ap.add_argument("--temperature", type=float, default=1.0)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
key = jax.random.PRNGKey(0)
params, _ = init_params(cfg, key)
B, P, G = args.batch, args.prompt_len, args.gen
max_seq = P + G

spec = context_spec(cfg, B)
context = None if spec is None else jax.random.normal(key, spec.shape, cfg.dtype)
prompt = jax.random.randint(key, (B, P), 1, cfg.vocab_size)

# -- prefill: run the prompt through the decode path to fill the caches ------
cache = init_cache(params, cfg, B, max_seq, context=context)
step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
t0 = time.perf_counter()
for i in range(P):
    logits, cache = step(params, cache, prompt[:, i:i + 1])
prefill_s = time.perf_counter() - t0

# -- decode: sample token by token -------------------------------------------
tokens = [jnp.argmax(logits[:, -1], -1, keepdims=True)]
t0 = time.perf_counter()
for i in range(G - 1):
    logits, cache = step(params, cache, tokens[-1])
    if args.temperature > 0:
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits[:, -1] / args.temperature,
                                     axis=-1)[:, None]
    else:
        nxt = jnp.argmax(logits[:, -1], -1, keepdims=True)
    tokens.append(nxt)
decode_s = time.perf_counter() - t0
gen = np.asarray(jnp.concatenate(tokens, axis=1))

print(f"arch={cfg.name}  batch={B}  prompt={P}  generated={G}")
print(f"prefill: {prefill_s:.2f}s ({B*P/prefill_s:.0f} tok/s)   "
      f"decode: {decode_s:.2f}s ({B*(G-1)/decode_s:.0f} tok/s)")
print("sampled ids (seq 0):", gen[0, :16].tolist(), "...")
print(f"cache position after run: {int(cache['pos'])} == {P + G - 1}")
