"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic corpus, with gradient accumulation (in-mapper combining),
monoid metrics, stream statistics, checkpointing and preemption handling.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dim 512]

(CPU-friendly defaults; pass --steps 300 for the full curve. The same
TrainerConfig drives the production mesh via launch/steps.py.)
"""
import argparse

import repro.configs as configs
from repro.models import ModelConfig
from repro.launch.train import TrainerConfig, train
from repro.runtime import PreemptionHandler


def make_100m(dim: int) -> ModelConfig:
    """~100M params at dim=512: 8L, d_ff=2048, vocab 32k."""
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=8, d_model=dim,
        num_heads=8, num_kv_heads=4, head_dim=dim // 8, d_ff=4 * dim,
        vocab_size=32_768, qk_norm=True, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = make_100m(args.dim)
    n = cfg.num_params()
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    # register the config under a temp name so TrainerConfig can find it
    configs._MODULES["lm-100m"] = type(
        "M", (), {"ARCH_ID": "lm-100m",
                  "config": staticmethod(lambda: cfg),
                  "smoke_config": staticmethod(lambda: cfg)})

    tc = TrainerConfig(arch="lm-100m", smoke=False, steps=args.steps,
                       global_batch=args.batch, seq_len=args.seq,
                       microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    out = train(tc, preemption=PreemptionHandler())
    hist = out["history"]
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {out['steps_done']} steps")
    from repro.data import make_stream_stats, summarize
    stats = summarize(make_stream_stats(), out["stream_stats"])
    print(f"corpus stats (monoid stream): {stats['tokens']} tokens, "
          f"~{stats['approx_distinct']:.0f} distinct")


if __name__ == "__main__":
    main()
