"""Training-step throughput on the smoke configs (CPU wall-clock — the
per-arch structural numbers for the real mesh come from the roofline table)."""
import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import init_opt_state
from .common import row


def bench_arch(arch: str, steps: int = 3, B: int = 4, S: int = 64):
    cfg = get_config(arch, smoke=True)
    mesh = make_host_mesh()
    built = make_train_step(cfg, mesh, ShapeCell("b", "train", S, B),
                            donate=False)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    from repro.configs import context_spec
    spec = context_spec(cfg, B)
    if spec is not None:
        batch["context"] = jax.random.normal(
            jax.random.PRNGKey(2), (B,) + spec.shape[1:], cfg.dtype)
    jax.block_until_ready(built.fn(params, opt, batch))  # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        p2, o2, m = built.fn(params, opt, batch)
        jax.block_until_ready(m["loss"])
    us = (time.perf_counter() - t0) / steps * 1e6
    row(f"train_step/{arch}(smoke)", us,
        f"tok_s={B*S/(us/1e6):.0f};loss={float(m['loss']):.3f}")


def main():
    for arch in ("qwen3-0.6b", "gemma3-1b", "jamba-v0.1-52b",
                 "deepseek-v2-236b", "xlstm-1.3b", "whisper-small"):
        bench_arch(arch)


if __name__ == "__main__":
    main()
