"""Prefix KV-cache serving path: warm-vs-cold TTFT under shared-prefix load.

The prefix-tier rows CI guards (``prefix_`` in ``run.py --compare``; the
rate/bytes rows are HIGHER-is-better, inverted by the gate):

* ``prefix_ttft_p50/cold``  — TTFT p50 over a shared-prefix Poisson trace
  with the radix prefix cache DISABLED (every admission prefills the full
  prompt at its bucket).
* ``prefix_ttft_p50/warm``  — the same trace, prefix cache on: admissions
  scatter the cached prefix KV rows and prefill only the suffix, bucketed
  on SUFFIX length.  The intra-run gate (``run.py check_prefix_rows``)
  requires warm <= 0.6x cold at >= 50% shared traffic.
* ``prefix_hit_rate``       — percent of prompt tokens served from the trie
  over the measured pass.
* ``prefix_bytes_saved``    — KV bytes not re-prefilled over the measured
  pass (the fold-accounted savings).

Both engines replay the IDENTICAL trace; a full warmup pass first compiles
every (k, bucket) shape ladder program on each engine (and populates the
warm engine's trie), so the measured pass is steady-state serving.
"""
import numpy as np

from repro.launch.serve import build_engine, serve_trace
from repro.runtime.engine import ServeConfig

from .common import row

ARCH = "qwen3-0.6b"
SLOTS = 4
BUCKETS = (4, 16)
MAX_NEW = 4
BLOCK = 4
SHARED_LEN = 12          # 3 trie blocks; suffixes of 1..4 land in bucket 4
SHARED_FRAC = 0.75       # >= 50%: the intra-run TTFT gate applies
TRACE_REQUESTS = 16
TRACE_RATE_HZ = 100.0


def shared_prefix_trace(rng, vocab):
    """Poisson arrivals where SHARED_FRAC of prompts open with one fixed
    SHARED_LEN-token prefix (the system-prompt workload shape)."""
    shared = rng.integers(1, vocab, SHARED_LEN).tolist()
    t, out = 0.0, []
    for _ in range(TRACE_REQUESTS):
        t += float(rng.exponential(1.0 / TRACE_RATE_HZ))
        if rng.random() < SHARED_FRAC:
            suffix = rng.integers(1, vocab, int(rng.integers(1, 5))).tolist()
            prompt = shared + suffix
        else:
            prompt = rng.integers(1, vocab, int(rng.integers(2, 5))).tolist()
        out.append((t, prompt, MAX_NEW, 0))
    return out


def ttft_p50_us(results):
    return float(np.percentile(np.array([r.ttft_s for r in results]), 50)) \
        * 1e6


def main():
    cfg_warm = ServeConfig(arch=ARCH, num_slots=SLOTS,
                           prefill_buckets=BUCKETS, max_new_tokens=MAX_NEW,
                           prefill_batch=SLOTS, prefix_block=BLOCK)
    cfg_cold = ServeConfig(arch=ARCH, num_slots=SLOTS,
                           prefill_buckets=BUCKETS, max_new_tokens=MAX_NEW,
                           prefill_batch=SLOTS, prefix_cache=False)
    warm = build_engine(cfg_warm)
    cold = build_engine(cfg_cold)

    rng = np.random.default_rng(0)
    vocab = warm.backend.vocab_size
    trace = shared_prefix_trace(rng, vocab)

    # warmup pass: compiles the whole (k, bucket) ladder on both engines and
    # populates the warm engine's trie with the shared prefix
    serve_trace(warm, trace)
    serve_trace(cold, trace)

    hit0 = (warm.prefix.stats.hit_tokens, warm.prefix.stats.prompt_tokens,
            warm.prefix.stats.bytes_saved)
    warm_results, _ = serve_trace(warm, trace)
    cold_results, _ = serve_trace(cold, trace)

    hit_tokens = warm.prefix.stats.hit_tokens - hit0[0]
    prompt_tokens = warm.prefix.stats.prompt_tokens - hit0[1]
    bytes_saved = warm.prefix.stats.bytes_saved - hit0[2]
    hit_rate = 100.0 * hit_tokens / max(prompt_tokens, 1)

    label = (f"[{ARCH},slots={SLOTS},"
             f"buckets={'x'.join(map(str, BUCKETS))},"
             f"shared={SHARED_LEN}tok@{SHARED_FRAC:.0%},"
             f"reqs={TRACE_REQUESTS}]")
    cold_us = ttft_p50_us(cold_results)
    warm_us = ttft_p50_us(warm_results)
    row(f"prefix_ttft_p50/cold{label}", cold_us, "full-prompt prefill")
    row(f"prefix_ttft_p50/warm{label}", warm_us,
        f"{warm_us / max(cold_us, 1e-9):.2f}x of cold (gate: <= 0.60x)")
    row(f"prefix_hit_rate{label}", hit_rate,
        f"{hit_tokens}/{prompt_tokens} prompt tokens from the trie "
        "(HIGHER is better)")
    row(f"prefix_bytes_saved{label}", float(bytes_saved),
        f"KV bytes not re-prefilled; {warm.prefix.stats.evictions} "
        "evictions (HIGHER is better)")


if __name__ == "__main__":
    main()
