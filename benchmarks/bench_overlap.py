"""Overlap benches: the async double-buffered fold vs the sync crossing.

Runs on an 8-fake-device (2 pod x 4 ici) mesh — the CI overlap pass sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before launching
this module via ``benchmarks/run.py --overlap`` (with fewer devices the
section prints a comment and emits nothing, so a bare local run never
fails).

Rows:

* ``overlap_step_us/sync_dense``  — one ICI+DCN crossing of the summed
  microbatches (``layout='scan'``), the baseline every other row is read
  against.
* ``overlap_step_us/sync_lossy``  — same shape with a ``lossy=`` top-k
  annotation: compressed DCN crossing + error feedback.
* ``overlap_step_us/async_dbuf``  — FORCED ``layout='async'``: one crossing
  per microbatch, pipelined.  Informational: on CPU fake devices the host
  collectives cannot actually overlap compute, so this row documents the
  un-hidden cost of n crossings rather than a win.
* ``overlap_step_us/auto``        — the planner's argmin between the two
  shapes.  Gated by ``run.py --compare``: auto must stay within 1.10x of
  sync_dense (the cost model may not buy overlap that is not there).
* ``overlap_frac/{modeled,measured}_pct`` — the plan's promised hidden
  fraction of DCN time next to the observed one (percent; measured is
  1 - async/sequential over the same n-crossing schedule).
* ``overlap_bytes/{dense,lossy}`` — per-step DCN bytes of the dense vs the
  compressed crossing, read off the plan.  Gated: lossy < dense.

Every figure flows through :func:`repro.core.mapreduce.fold_stats` — the
same per-step record the straggler monitor consumes, so the bench and the
health signal can never drift apart.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execute_fold, monoids, plan_fold
from repro.core.mapreduce import fold_stats
from .common import row, time_fn

_MESH_SHAPE = (2, 4)              # (pod, x): 2-way DCN, 4-way ICI
_AXES = ("pod", "x")
_FOLD_AXES = ("x", "pod")         # ICI first, then the slow axis
_LOSSY = "topk:0.05"
_GUARD = dict(warmup=3, iters=9)  # gated rows: extra iters for the median


def _mesh():
    return jax.make_mesh(_MESH_SHAPE, _AXES,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _sharded_step(mesh, fn):
    """jit(shard_map(...)): each device folds its own (n_mb, d) block.

    check_vma=False: the async tier's scan carry replication defeats the
    static checker (see execute_fold's docstring)."""
    spec = jax.sharding.PartitionSpec(_AXES)
    return jax.jit(jax.shard_map(
        lambda v: fn(v[0]), mesh=mesh, in_specs=(spec,),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False))


def bench_overlap(n_mb: int = 4, d: int = 1 << 16):
    if len(jax.devices()) < 8:
        print("# overlap section skipped: needs 8 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return
    mesh = _mesh()
    m = monoids.sum_
    rng = np.random.default_rng(17)
    data = jnp.asarray(
        rng.normal(size=(8, n_mb, d)).astype(np.float32))
    shape = jax.ShapeDtypeStruct((n_mb, d), jnp.float32)
    sizes = dict(zip(_AXES, _MESH_SHAPE))

    # plans (no FLOPs): the modeled side of every derived column below
    plan_sync = plan_fold(m, shape, mesh_axes=_FOLD_AXES, layout="scan",
                          axis_sizes=sizes)
    plan_async = plan_fold(m, shape, mesh_axes=_FOLD_AXES, layout="async",
                           axis_sizes=sizes)
    plan_lossy = plan_fold(m, shape, mesh_axes=_FOLD_AXES, layout="scan",
                           axis_sizes=sizes, lossy=_LOSSY)
    plan_auto = plan_fold(m, shape, mesh_axes=_FOLD_AXES, layout="auto",
                          axis_sizes=sizes)

    sync_dense = _sharded_step(mesh, lambda v: execute_fold(
        m, v, mesh_axes=_FOLD_AXES, layout="scan", mesh=mesh))
    sync_lossy = _sharded_step(mesh, lambda v: execute_fold(
        m, v, mesh_axes=_FOLD_AXES, layout="scan", mesh=mesh, lossy=_LOSSY))
    async_dbuf = _sharded_step(mesh, lambda v: execute_fold(
        m, v, mesh_axes=_FOLD_AXES, layout="async", mesh=mesh))
    auto = _sharded_step(mesh, lambda v: execute_fold(
        m, v, mesh_axes=_FOLD_AXES, layout="auto", mesh=mesh))

    def _sequential(v):
        # the async schedule with the pipelining taken out: one sync fold
        # (local + full crossing) per microbatch, chained — the baseline
        # the measured overlap fraction is read against
        acc = jnp.zeros((d,), jnp.float32)
        for i in range(n_mb):
            acc = acc + execute_fold(m, v[i:i + 1], mesh_axes=_FOLD_AXES,
                                     layout="scan", mesh=mesh)
        return acc

    sequential = _sharded_step(mesh, _sequential)

    sync_us = time_fn(sync_dense, data, **_GUARD)
    row("overlap_step_us/sync_dense", sync_us,
        f"predicted_us={plan_sync.predicted_us:.1f};one crossing of the "
        f"summed microbatches")
    lossy_us = time_fn(sync_lossy, data, **_GUARD)
    row("overlap_step_us/sync_lossy", lossy_us,
        f"predicted_us={plan_lossy.predicted_us:.1f};lossy={plan_lossy.lossy}")
    async_us = time_fn(async_dbuf, data, **_GUARD)
    seq_us = time_fn(sequential, data)
    measured_frac = max(0.0, 1.0 - async_us / max(seq_us, 1e-9))
    row("overlap_step_us/async_dbuf", async_us,
        f"predicted_us={plan_async.predicted_us:.1f};modeled_overlap="
        f"{plan_async.overlap_modeled:.0%};sequential_us={seq_us:.1f}")
    auto_us = time_fn(auto, data, **_GUARD)
    chose = ("async" if plan_auto.local_tier.kind == "async" else "sync")
    row("overlap_step_us/auto", auto_us,
        f"chose={chose};candidates=" + ";".join(
            f"{k}={us:.1f}" for k, us in plan_auto.plan_candidate_us))

    # modeled vs measured overlap + dense vs wire bytes, all through the
    # ShuffleStats record (fault_tolerance's StragglerMonitor reads the
    # exact same fields)
    stats = fold_stats(plan_async).with_measured(async_us,
                                                 overlap=measured_frac)
    row("overlap_frac/modeled_pct", stats.overlap_modeled * 100.0,
        "plan's hidden fraction of DCN time (percent)")
    row("overlap_frac/measured_pct", (stats.overlap_measured or 0.0) * 100.0,
        f"1 - async/sequential; collapse={stats.overlap_collapse():.3f} "
        "(CPU fake devices cannot overlap host collectives; ~0 expected)")
    lstats = fold_stats(plan_lossy)
    row("overlap_bytes/dense", float(lstats.dense_wire_bytes),
        "per-device DCN bytes, dense crossing")
    row("overlap_bytes/lossy", float(lstats.lossy_wire_bytes),
        f"per-device DCN bytes, {lstats.lossy}; compression="
        f"{lstats.compression_ratio():.1f}x")


def main():
    bench_overlap()


if __name__ == "__main__":
    main()
