"""Batched serving path: decode step + per-step keyed-fold aggregation.

The serve-tier rows CI guards (``serve_`` prefix in ``run.py --compare``):

* ``serve_decode_step``   — one batched decode step (model forward + cache
  update) on the tiny smoke config.
* ``serve_metrics_fold``  — the per-step aggregation alone: ONE
  planner-lowered masked keyed fold carrying logprob sums / token counts /
  stop hits for the whole batch.
* ``serve_batch_e2e``     — a full ragged batch decoded to completion
  through ``run_batched_decode`` (prefill + decode + metrics folds),
  including fresh-cache setup, reported with tok/s derived.

On CPU the Pallas tier runs in interpret mode (kernels/ops.py default);
this is the CI `serve-smoke` workload.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import (build_serve_step, decode_metrics_init,
                                decode_metrics_step, run_batched_decode)
from repro.runtime.batcher import RequestBatcher

from .common import row, time_fn

ARCH = "qwen3-0.6b"
MAX_BATCH = 4
MAX_PROMPT = 16
GEN = 8


def main():
    cfg, built, params, make_cache = build_serve_step(
        ARCH, max_batch=MAX_BATCH, max_seq=MAX_PROMPT + GEN)

    # -- one decode step ----------------------------------------------------
    cache = make_cache()
    tok = jnp.ones((MAX_BATCH, 1), jnp.int32)
    us = time_fn(lambda: built.fn(params, cache, tok)[0])
    row(f"serve_decode_step[{cfg.name},B={MAX_BATCH}]", us,
        f"{MAX_BATCH * 1e6 / us:.0f} tok/s")

    # -- the per-step aggregation fold (request slot == segment id) ---------
    B = 8
    rng = np.random.default_rng(0)
    table = decode_metrics_init(B)
    logits = jnp.asarray(rng.normal(size=(B, cfg.vocab_size)).astype(np.float32))
    sampled = jnp.asarray(rng.integers(0, cfg.vocab_size, B).astype(np.int32))
    slots = jnp.arange(B, dtype=jnp.int32)
    active = jnp.asarray(rng.integers(0, 2, B).astype(bool))
    # µs-scale call: take a bigger sample so the CI regression gate (20%)
    # sees the median, not scheduler noise
    us = time_fn(lambda: decode_metrics_step(table, logits, sampled, slots,
                                             active, num_slots=B, eos_id=0),
                 warmup=5, iters=30)
    row(f"serve_metrics_fold[B={B},cols=3]", us, "one keyed fold/step")

    # -- a ragged batch end-to-end ------------------------------------------
    batcher = RequestBatcher(max_batch_size=MAX_BATCH, max_wait_s=0.0)
    for i in range(MAX_BATCH - 1):           # deliberately partial: ragged
        plen = 4 + 3 * i
        batcher.submit(rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=GEN)
    batch = batcher.flush(force=True)

    def e2e():
        res = run_batched_decode(built, params, make_cache(), batch,
                                 eos_id=0, temperature=0.0)
        return res.metrics["tokens"]

    us = time_fn(e2e, warmup=1, iters=3)
    toks = int(np.sum(e2e()))
    row(f"serve_batch_e2e[{cfg.name},reqs={len(batch)}/{MAX_BATCH},gen={GEN}]",
        us, f"{toks * 1e6 / us:.0f} tok/s")


if __name__ == "__main__":
    main()
