"""Continuous-batching serving path: engine arrival trace + step/fold rows.

The serve-tier rows CI guards (``serve_`` prefix in ``run.py --compare``):

* ``serve_decode_step``     — one batched decode step (model forward + cache
  update) on the tiny smoke config (fixed-shape ``build_serve_step`` path).
* ``serve_metrics_fold``    — the per-step aggregation alone: ONE
  planner-lowered masked keyed fold carrying logprob sums / token counts /
  stop hits for the whole batch.
* ``serve_batch_e2e``       — a ragged batch decoded to completion through
  the deprecated ``run_batched_decode`` shim (now engine-backed).
* ``serve_ttft_p50/p99``    — time-to-first-token percentiles over a
  synthetic Poisson arrival trace through the ContinuousEngine (rolling
  slots, bucketed prefill); µs from submit to the streamed first token.
* ``serve_tokens_per_sec``  — aggregate decode throughput over the same
  trace, reported as µs/token so the lower-is-better gate applies.

On CPU the Pallas tier runs in interpret mode (kernels/ops.py default);
this is the CI `serve-smoke` workload.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import (build_engine, build_serve_step,
                                decode_metrics_init, decode_metrics_step,
                                poisson_trace, run_batched_decode,
                                serve_trace)
from repro.runtime.batcher import RequestBatcher
from repro.runtime.engine import ServeConfig

from .common import row, time_fn

ARCH = "qwen3-0.6b"
CONFIG = ServeConfig(arch=ARCH, num_slots=4, prefill_buckets=(8, 16),
                     max_new_tokens=8)
TRACE_REQUESTS = 12
TRACE_RATE_HZ = 100.0


def main():
    cfg, built, params, make_cache = build_serve_step(CONFIG)

    # -- one decode step ----------------------------------------------------
    cache = make_cache()
    tok = jnp.ones((CONFIG.num_slots, 1), jnp.int32)
    us = time_fn(lambda: built.fn(params, cache, tok)[0])
    row(f"serve_decode_step[{cfg.name},B={CONFIG.num_slots}]", us,
        f"{CONFIG.num_slots * 1e6 / us:.0f} tok/s")

    # -- the per-step aggregation fold (request slot == segment id) ---------
    B = 8
    rng = np.random.default_rng(0)
    table = decode_metrics_init(B)
    logits = jnp.asarray(rng.normal(size=(B, cfg.vocab_size)).astype(np.float32))
    sampled = jnp.asarray(rng.integers(0, cfg.vocab_size, B).astype(np.int32))
    slots = jnp.arange(B, dtype=jnp.int32)
    active = jnp.asarray(rng.integers(0, 2, B).astype(bool))
    # µs-scale call: take a bigger sample so the CI regression gate (20%)
    # sees the median, not scheduler noise
    us = time_fn(lambda: decode_metrics_step(table, logits, sampled, slots,
                                             active, num_slots=B, eos_id=0),
                 warmup=5, iters=30)
    row(f"serve_metrics_fold[B={B},cols=3]", us, "one keyed fold/step")

    # -- a ragged batch end-to-end through the deprecated shim --------------
    engine = build_engine(CONFIG)
    batcher = RequestBatcher(max_batch_size=CONFIG.num_slots, max_wait_s=0.0)
    for i in range(CONFIG.num_slots - 1):    # deliberately partial: ragged
        plen = 4 + 3 * i
        batcher.submit(rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=CONFIG.max_new_tokens)
    batch = batcher.flush(force=True)

    def e2e():
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            res = run_batched_decode(engine, batch)
        return res.metrics["tokens"]

    us = time_fn(e2e, warmup=1, iters=3)
    toks = int(np.sum(e2e()))
    row(f"serve_batch_e2e[{cfg.name},reqs={len(batch)}/{CONFIG.num_slots},"
        f"gen={CONFIG.max_new_tokens}]", us, f"{toks * 1e6 / us:.0f} tok/s")

    # -- Poisson arrival trace through the rolling engine -------------------
    # same engine: its bucket ladder is already compiled (the warmup above
    # touched every shape), so the trace measures steady-state serving
    trace = poisson_trace(rng, TRACE_REQUESTS, TRACE_RATE_HZ,
                          min_prompt=4, max_prompt=CONFIG.max_prompt,
                          vocab=cfg.vocab_size,
                          max_new=CONFIG.max_new_tokens)
    # touch the 8-bucket too (the shim batch above may only hit 16)
    pre = [(0.0, [1, 2, 3], 1)]
    serve_trace(engine, pre)
    results, wall = serve_trace(engine, trace)

    ttfts_us = np.array([r.ttft_s for r in results]) * 1e6
    new_tokens = sum(len(r.tokens) for r in results)
    label = (f"[{cfg.name},slots={CONFIG.num_slots},"
             f"buckets={'x'.join(map(str, CONFIG.prefill_buckets))},"
             f"reqs={TRACE_REQUESTS},rate={TRACE_RATE_HZ:.0f}]")
    row(f"serve_ttft_p50{label}", float(np.percentile(ttfts_us, 50)),
        "submit -> first token")
    row(f"serve_ttft_p99{label}", float(np.percentile(ttfts_us, 99)),
        "tail TTFT")
    row(f"serve_tokens_per_sec{label}", wall * 1e6 / max(new_tokens, 1),
        f"{new_tokens / wall:.0f} tok/s, "
        f"{engine.stats.slot_reuses} slot reuses")


if __name__ == "__main__":
    main()
