"""Pallas kernels vs jnp references. On this CPU container the kernels run
in interpret mode (so wall-times favor the XLA refs); the 'derived' column
carries the structural quantities that transfer to TPU: MXU FLOPs per block,
VMEM working set, HBM traffic avoided."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import row, time_fn


def bench_segment_fold(n: int = 1 << 13, d: int = 64, s: int = 128):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    us_k = time_fn(lambda: ops.segment_fold(vals, segs, s, block_n=512))
    us_r = time_fn(jax.jit(lambda: ref.segment_fold_ref(vals, segs, s)))
    mxu_flops = 2 * n * s * d
    row("segment_fold/pallas(interp)", us_k, f"mxu_flops={mxu_flops}")
    row("segment_fold/xla_ref", us_r, f"vmem_acc_bytes={s*d*4}")


def bench_cms(n: int = 1 << 14, depth: int = 4, width: int = 2048):
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
    us_k = time_fn(lambda: ops.cms_update(toks, depth, width))
    us_r = time_fn(jax.jit(lambda: ref.cms_update_ref(toks, depth, width)))
    row("cms_update/pallas(interp)", us_k, f"sketchB={depth*width*4}")
    row("cms_update/xla_ref", us_r, f"exact_tableB={(1<<20)*4}"
        f";compression={(1<<20)*4/(depth*width*4):.0f}x")


def bench_stripes(n: int = 4096, vocab: int = 256, window: int = 4):
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, vocab, n).astype(np.int32))
    us_k = time_fn(lambda: ops.stripes(toks, vocab, window, block_n=512))
    us_r = time_fn(jax.jit(lambda: ref.stripes_ref(toks, vocab, window)))
    row("stripes/pallas(interp)", us_k,
        f"mxu_flops={2*2*window*n*vocab*vocab//1}")
    row("stripes/xla_ref", us_r, f"tableB={vocab*vocab*4}")


def bench_flash_attention(B: int = 1, H: int = 4, S: int = 512, d: int = 64):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(B, H, S, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, d)).astype(np.float32))
    us_k = time_fn(lambda: ops.flash_attn(q, k, v, block_q=128, block_k=128))
    us_r = time_fn(jax.jit(lambda: ref.flash_attention_ref(q, k, v)))
    hbm_avoided = B * H * S * S * 4   # the f32 score matrix never leaves VMEM
    row("flash_attn/pallas(interp)", us_k, f"hbm_avoidedB={hbm_avoided}")
    row("flash_attn/xla_ref", us_r, f"scoresB={hbm_avoided}")


def main():
    bench_segment_fold()
    bench_cms()
    bench_stripes()
    bench_flash_attention()


if __name__ == "__main__":
    main()
