"""Benchmark suite entry point. One section per paper artifact/table.

Prints ``name,us_per_call,derived`` CSV rows. The roofline table (the per-
(arch x shape x mesh) structural numbers) is rendered separately by
``python -m benchmarks.roofline`` from the dry-run JSONs.

``--quick`` runs only the fast algorithm/aggregation/sketch sections (the
CI bench-smoke job); ``--json PATH`` additionally writes every row to a
``BENCH_*.json`` artifact so the perf trajectory accumulates per commit.
"""
import argparse
import json
import platform
import sys

from . import (bench_aggregation, bench_kernels, bench_mapreduce,
               bench_sketches, bench_train)
from . import common


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast sections only (CI bench-smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_*.json artifact")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    print("# -- Algorithms 1/3/4: mean-by-key & word count ------------------")
    bench_mapreduce.main()
    print("# -- aggregation layer: folds, grad accum, metrics, compression --")
    bench_aggregation.main()
    print("# -- sketch monoids (paper section 3) ----------------------------")
    bench_sketches.main()
    if not args.quick:
        print("# -- Pallas kernels vs XLA refs (interpret mode on CPU) ----------")
        bench_kernels.main()
        print("# -- end-to-end train step (smoke configs, CPU) ------------------")
        bench_train.main()

    if args.json:
        import jax
        payload = {
            "quick": args.quick,
            "python": platform.python_version(),
            "jax": jax.__version__,
            "platform": platform.platform(),
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} ({len(common.ROWS)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
