"""Benchmark suite entry point. One section per paper artifact/table.

Prints ``name,us_per_call,derived`` CSV rows. The roofline table (the per-
(arch x shape x mesh) structural numbers) is rendered separately by
``python -m benchmarks.roofline`` from the dry-run JSONs.
"""
from . import (bench_aggregation, bench_kernels, bench_mapreduce,
               bench_sketches, bench_train)


def main() -> None:
    print("name,us_per_call,derived")
    print("# -- Algorithms 1/3/4: mean-by-key & word count ------------------")
    bench_mapreduce.main()
    print("# -- Pallas kernels vs XLA refs (interpret mode on CPU) ----------")
    bench_kernels.main()
    print("# -- aggregation layer: folds, grad accum, metrics, compression --")
    bench_aggregation.main()
    print("# -- sketch monoids (paper section 3) ----------------------------")
    bench_sketches.main()
    print("# -- end-to-end train step (smoke configs, CPU) ------------------")
    bench_train.main()


if __name__ == "__main__":
    main()
