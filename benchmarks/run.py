"""Benchmark suite entry point. One section per paper artifact/table.

Prints ``name,us_per_call,derived`` CSV rows. The roofline table (the per-
(arch x shape x mesh) structural numbers) is rendered separately by
``python -m benchmarks.roofline`` from the dry-run JSONs.

``--quick`` runs only the fast algorithm/aggregation/sketch sections (the
CI bench-smoke job); ``--serve`` runs only the batched serving section (the
CI serve-smoke job, interpret mode on CPU); ``--json PATH`` additionally
writes every row to a ``BENCH_*.json`` artifact so the perf trajectory
accumulates per commit; ``--compare OLD_JSON`` diffs the fresh run against
a previous artifact and exits non-zero on a >20% throughput regression in
the guarded hot rows (``segment_fold``/``mean_by_key`` — the planner's
kernel tier — and the ``serve_`` decode/fold rows).  A missing baseline is
skipped with a warning, or is an error under ``--require-baseline`` (CI on
main: the trajectory must never silently restart).
"""
import argparse
import json
import platform
import sys

from . import (bench_aggregation, bench_kernels, bench_mapreduce,
               bench_overlap, bench_plan, bench_prefix, bench_serve,
               bench_sketches, bench_train, bench_windows)
from . import common

# rows guarded by --compare: the planner-lowered hot paths + the serve tier
# + the overlap section's step rows + the windowed-streaming event rates
# + the prefix-cache serving rows
GUARDED_PREFIXES = ("segment_fold", "mean_by_key", "plan_auto", "serve_",
                    "overlap_step", "window_events", "prefix_")
# guarded rows where BIGGER is better (hit rates, bytes saved): the compare
# gate inverts — fail when the new value drops below old / tolerance
HIGHER_IS_BETTER = ("prefix_hit_rate", "prefix_bytes_saved")
REGRESSION_TOLERANCE = 1.20   # fail on >20% slower than the previous artifact
# intra-run gate for the prefix-cache section: warm TTFT p50 must be at
# most this fraction of cold at >= 50% shared-prefix traffic — reusing
# cached KV rows that does not cut time-to-first-token is dead weight
PREFIX_TOLERANCE = 0.60
# intra-run gate: layout='auto' must stay within this factor of the BEST
# forced layout for the same case — the cost model may not mis-place a fold
AUTO_TOLERANCE = 1.50
# intra-run gate for the overlap section: the sync-vs-async argmin must not
# cost more than timing noise over always-sync (on hardware where the DCN
# crossing cannot actually hide, auto has to keep choosing sync)
OVERLAP_TOLERANCE = 1.10


def compare_rows(new_rows, old_rows, *, tolerance: float = REGRESSION_TOLERANCE):
    """Return [(name, old_us, new_us), ...] for guarded rows that regressed."""
    old = {r["name"]: float(r["us_per_call"]) for r in old_rows
           if isinstance(r, dict) and "name" in r and "us_per_call" in r}
    regressions = []
    for r in new_rows:
        name = r["name"]
        if not any(name.startswith(p) for p in GUARDED_PREFIXES):
            continue
        if name not in old or old[name] <= 0:
            continue
        new_us = float(r["us_per_call"])
        if any(name.startswith(p) for p in HIGHER_IS_BETTER):
            if new_us < old[name] / tolerance:
                regressions.append((name, old[name], new_us))
        elif new_us > old[name] * tolerance:
            regressions.append((name, old[name], new_us))
    return regressions


def check_auto_rows(rows, *, tolerance: float = AUTO_TOLERANCE):
    """Gate the planner's auto decisions against the forced layouts.

    For each ``plan_auto/<case>`` row, find the fastest
    ``plan_forced/<case>/<layout>`` row from the SAME run; auto slower than
    ``tolerance x best`` means the cost model chose a losing tier.  Returns
    [(case, auto_us, best_layout, best_us), ...] violations.
    """
    auto, forced = {}, {}
    for r in rows:
        name = str(r.get("name", ""))
        us = float(r.get("us_per_call", 0.0))
        if name.startswith("plan_auto/"):
            auto[name.split("/", 1)[1]] = us
        elif name.startswith("plan_forced/"):
            _, case, layout = name.split("/", 2)
            forced.setdefault(case, []).append((layout, us))
    violations = []
    for case, auto_us in auto.items():
        if not forced.get(case):
            continue
        best_layout, best_us = min(forced[case], key=lambda t: t[1])
        if best_us > 0 and auto_us > best_us * tolerance:
            violations.append((case, auto_us, best_layout, best_us))
    return violations


def check_overlap_rows(rows, *, tolerance: float = OVERLAP_TOLERANCE):
    """Gate the overlap section against itself (no baseline needed).

    * ``overlap_step_us/auto`` must stay within ``tolerance x`` the measured
      ``overlap_step_us/sync_dense`` — the planner's sync-vs-async argmin
      may not buy overlap the hardware does not deliver.
    * ``overlap_bytes/lossy`` must be strictly below ``overlap_bytes/dense``
      — a lossy annotation that does not shrink the DCN crossing is a bug.

    Returns a list of human-readable violation strings; empty when the
    section did not run (no 8-device mesh) or everything held.
    """
    vals = {str(r.get("name", "")): float(r.get("us_per_call", 0.0))
            for r in rows}
    violations = []
    auto = vals.get("overlap_step_us/auto")
    sync = vals.get("overlap_step_us/sync_dense")
    if auto is not None and sync is not None and sync > 0 \
            and auto > sync * tolerance:
        violations.append(
            f"overlap_step_us/auto {auto:.1f}us > {tolerance:.2f}x "
            f"sync_dense {sync:.1f}us ({auto / sync:.2f}x): the planner "
            "bought overlap that is not there")
    dense = vals.get("overlap_bytes/dense")
    lossy = vals.get("overlap_bytes/lossy")
    if dense is not None and lossy is not None and lossy >= dense:
        violations.append(
            f"overlap_bytes/lossy {lossy:.0f}B >= dense {dense:.0f}B: "
            "the lossy annotation moved no fewer bytes than the dense "
            "crossing")
    return violations


def check_prefix_rows(rows, *, tolerance: float = PREFIX_TOLERANCE):
    """Gate the prefix-cache section against itself (no baseline needed).

    * ``prefix_ttft_p50/warm`` must be <= ``tolerance x`` the measured
      ``prefix_ttft_p50/cold`` from the SAME run — the trace carries >= 50%
      shared-prefix traffic, so a prefix cache that does not cut TTFT by
      the declared factor is not pulling its weight.
    * ``prefix_hit_rate`` must be > 0 — a gate run where nothing hit the
      trie measured the wrong workload.

    Returns a list of human-readable violation strings; empty when the
    section did not run or everything held.
    """
    warm = cold = hit_rate = None
    for r in rows:
        name = str(r.get("name", ""))
        us = float(r.get("us_per_call", 0.0))
        if name.startswith("prefix_ttft_p50/warm"):
            warm = us
        elif name.startswith("prefix_ttft_p50/cold"):
            cold = us
        elif name.startswith("prefix_hit_rate"):
            hit_rate = us
    violations = []
    if warm is not None and cold is not None and cold > 0 \
            and warm > cold * tolerance:
        violations.append(
            f"prefix_ttft_p50/warm {warm:.1f}us > {tolerance:.2f}x cold "
            f"{cold:.1f}us ({warm / cold:.2f}x): prefix reuse did not cut "
            "TTFT under shared-prefix traffic")
    if hit_rate is not None and hit_rate <= 0:
        violations.append(
            "prefix_hit_rate is 0%: the shared-prefix trace never hit the "
            "trie")
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast sections only (CI bench-smoke)")
    ap.add_argument("--serve", action="store_true",
                    help="batched serving section only (CI serve-smoke)")
    ap.add_argument("--prefix", action="store_true",
                    help="prefix KV-cache section only (CI serve-smoke; "
                         "warm-vs-cold TTFT gate)")
    ap.add_argument("--overlap", action="store_true",
                    help="async-overlap section only (CI runs it under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_*.json artifact")
    ap.add_argument("--compare", default=None, metavar="OLD_JSON",
                    help="diff against a previous BENCH_*.json; exit 1 on "
                         ">20%% regression in segment_fold/mean_by_key/"
                         "serve_ rows")
    ap.add_argument("--require-baseline", action="store_true",
                    help="with --compare: a missing/unreadable baseline is "
                         "an error, not a silent skip")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if args.prefix:
        print("# -- radix prefix KV cache: warm vs cold TTFT --------------------")
        bench_prefix.main()
    elif args.serve:
        print("# -- batched serving path (planner-lowered keyed folds, CPU) -----")
        bench_serve.main()
    elif args.overlap:
        print("# -- async overlap: double-buffered DCN crossing vs sync ---------")
        bench_overlap.main()
    else:
        print("# -- Algorithms 1/3/4: mean-by-key & word count ------------------")
        bench_mapreduce.main()
        print("# -- aggregation layer: folds, planner tiers, grad accum, metrics --")
        bench_aggregation.main()
        print("# -- cost-model planner: auto vs forced layouts ------------------")
        bench_plan.main()
        print("# -- sketch monoids (paper section 3) ----------------------------")
        bench_sketches.main()
        print("# -- windowed streaming: two-stacks + keyed window folds ---------")
        bench_windows.main()
        if not args.quick:
            print("# -- Pallas kernels vs XLA refs (interpret mode on CPU) ----------")
            bench_kernels.main()
            print("# -- end-to-end train step (smoke configs, CPU) ------------------")
            bench_train.main()
            print("# -- batched serving path (planner-lowered keyed folds, CPU) -----")
            bench_serve.main()

    if args.json:
        import jax
        payload = {
            "quick": args.quick,
            "python": platform.python_version(),
            "jax": jax.__version__,
            "platform": platform.platform(),
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} ({len(common.ROWS)} rows)")

    if args.compare:
        # intra-run auto-vs-forced gate (no baseline needed): the planner's
        # layout='auto' rows must be within AUTO_TOLERANCE of the best
        # forced layout measured in THIS run
        overlap_violations = check_overlap_rows(common.ROWS)
        if overlap_violations:
            print("# OVERLAP GATE FAILED:")
            for v in overlap_violations:
                print(f"#   {v}")
            return 1
        prefix_violations = check_prefix_rows(common.ROWS)
        if prefix_violations:
            print("# PREFIX CACHE GATE FAILED:")
            for v in prefix_violations:
                print(f"#   {v}")
            return 1
        auto_violations = check_auto_rows(common.ROWS)
        if auto_violations:
            print(f"# PLANNER AUTO REGRESSION (> {AUTO_TOLERANCE:.2f}x best "
                  "forced layout):")
            for case, auto_us, best_layout, best_us in auto_violations:
                print(f"#   plan_auto/{case}: {auto_us:.1f}us vs best forced "
                      f"'{best_layout}' {best_us:.1f}us "
                      f"({auto_us / best_us:.2f}x)")
            return 1
        try:
            with open(args.compare) as f:
                old = json.load(f)
        except (OSError, ValueError):
            if args.require_baseline:
                print(f"# MISSING BASELINE: no usable previous artifact at "
                      f"{args.compare} and --require-baseline is set")
                return 1
            print(f"# no usable previous artifact at {args.compare}; "
                  "skipping diff")
            return 0
        old_rows = old.get("rows", []) if isinstance(old, dict) else []
        regressions = compare_rows(common.ROWS, old_rows)
        if regressions:
            print("# PERF REGRESSION (>20% vs previous artifact):")
            for name, old_us, new_us in regressions:
                print(f"#   {name}: {old_us:.1f}us -> {new_us:.1f}us "
                      f"({new_us / old_us:.2f}x)")
            return 1
        print(f"# perf diff vs {args.compare}: "
              f"guarded rows within {REGRESSION_TOLERANCE:.2f}x tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
