"""The paper's central efficiency claim, measured: Algorithms 1 vs 3 vs 4 on
mean-by-key — time per call, intermediate values materialized, shuffle bytes
(MapReduce cost model) and XLA collective bytes (TPU cost model).  All three
strategies lower through the execution planner (core/plan.py); the byte
columns are read off each strategy's plan."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import STRATEGIES, average_by_key_job, word_count_job
from .common import row, time_fn


def bench_mean_by_key(n: int = 1 << 14, keys: int = 64, shards: int = 8):
    rng = np.random.default_rng(0)
    records = {"key": jnp.asarray(rng.integers(0, keys, n).astype(np.int32)),
               "value": jnp.asarray(rng.normal(size=n).astype(np.float32))}
    job = average_by_key_job(keys)
    for strat in STRATEGIES:
        fn = jax.jit(lambda r, s=strat: job.run_local(r, strategy=s,
                                                      num_shards=shards))
        # guarded rows (CI --compare gate): extra iters to stabilize medians
        us = time_fn(fn, records, warmup=3, iters=9)
        st = job.stats(records, strategy=strat, num_shards=shards)
        row(f"mean_by_key/{strat}", us,
            f"inter={st.intermediate_values};shuffleB={st.shuffle_bytes_mapreduce};"
            f"xlaB={st.shuffle_bytes_xla};reduction={st.reduction_vs_naive():.1f}x;"
            f"plan={st.plan}")


def bench_word_count(n: int = 1 << 15, vocab: int = 1024, shards: int = 8):
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, vocab, n).astype(np.int32))
    job = word_count_job(vocab)
    for strat in STRATEGIES:
        fn = jax.jit(lambda t, s=strat: job.run_local(t, strategy=s,
                                                      num_shards=shards))
        us = time_fn(fn, toks)
        st = job.stats(toks, strategy=strat, num_shards=shards)
        row(f"word_count/{strat}", us,
            f"shuffleB={st.shuffle_bytes_mapreduce};"
            f"reduction={st.reduction_vs_naive():.1f}x")


def main():
    bench_mean_by_key()
    bench_word_count()


if __name__ == "__main__":
    main()
