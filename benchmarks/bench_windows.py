"""Windowed streaming throughput: two-stacks windows + planner-lowered folds.

Rows are **microseconds per event** (so the ``--compare`` regression gate
points the right way: bigger == slower), with events/s in the derived
column.  The headline ``window_events_per_sec`` row is the two-stacks
sliding-window push+query path — one amortized monoid combine per event —
and is guarded by ``run.py --compare`` alongside the batch
``tumbling_fold``/``session_fold`` rows (ONE planner-lowered keyed fold
over the whole stream).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids
from repro.data.windows import (SlidingWindow, session_fold, sessionize,
                                tumbling_fold)

from .common import row, time_fn


def _stream_rate(window: SlidingWindow, values, *, query_every: int = 8):
    """Push the whole stream (querying every few events), return us/event."""
    t0 = time.perf_counter()
    for i, v in enumerate(values):
        window.push(v)
        if i % query_every == 0:
            window.query()
    jax.block_until_ready(window.query())
    return (time.perf_counter() - t0) / len(values) * 1e6


def bench_sliding(n: int = 1500, size: int = 64) -> None:
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(x) for x in rng.normal(size=n).astype(np.float32)]
    w = SlidingWindow(monoids.sum_, size)
    us = _stream_rate(w, xs)
    row("window_events_per_sec", us,
        f"{1e6 / us:.0f} events/s sliding sum w={size} "
        f"({w.flip_combines / w.pushes:.2f} flip combines/event)")

    m = monoids.count_min(2, 64)
    items = [m.lift(jnp.asarray(x, jnp.int32))
             for x in rng.integers(0, 1000, 200)]
    w = SlidingWindow(m, 16)
    us = _stream_rate(w, items)
    row("window_events/sliding_cms", us,
        f"{1e6 / us:.0f} events/s sliding cms(2,64) w=16")


def bench_batch_folds(n: int = 4096) -> None:
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    ts = jnp.asarray(np.sort(rng.uniform(0, 64, n)).astype(np.float32))

    fold = jax.jit(lambda v, t: tumbling_fold(
        monoids.sum_, v, t, width=1.0, num_windows=64))
    us = time_fn(fold, vals, ts)
    row("window_events/tumbling_fold", us,
        f"{n} events -> 64 windows, {n / us * 1e6:.0f} events/s "
        "(one keyed fold)")

    users = rng.integers(0, 32, n)
    sids, nsess = sessionize(users, np.sort(rng.uniform(0, 600, n)), gap=5.0)
    sfold = jax.jit(lambda v, s: session_fold(
        monoids.sum_, v, s, nsess))
    us = time_fn(sfold, vals, jnp.asarray(sids))
    row("window_events/session_fold", us,
        f"{n} events -> {nsess} sessions, {n / us * 1e6:.0f} events/s "
        "(one keyed fold)")


def main() -> None:
    bench_sliding()
    bench_batch_folds()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
