"""Timing harness for the benchmark suite (CSV: name,us_per_call,derived).

Every row printed through :func:`row` is also recorded in :data:`ROWS`, so
``benchmarks/run.py --json`` can dump the whole run as a machine-readable
artifact (the ``BENCH_*.json`` files CI uploads per commit).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

ROWS: List[Dict[str, object]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us_per_call": round(us, 1), "derived": derived})
    print(f"{name},{us:.1f},{derived}")
