"""Cost-model planner benches: does ``layout='auto'`` pick a winner?

For each case, one ``plan_auto/<case>`` row (the planner's argmin choice)
and one ``plan_forced/<case>/<layout>`` row per feasible forced layout.
The CI gate (``benchmarks/run.py --compare``) checks auto stays within
tolerance of the BEST forced row — the cost model must not mis-place a
fold by more than timing noise.  Derived columns carry the plan's chain
and its predicted microseconds next to the measurement, so the artifact
history tracks modeled-vs-measured drift.

On TPU (``REPRO_INTERPRET=0``) the kernel layout is a candidate and its
row measures the real compiled Pallas kernel; on CPU the kernel tier is
infeasible for auto and is skipped (interpret-mode timings would poison
the comparison).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execute_fold, monoids, plan_fold
from .common import row, time_fn

# (case name, monoid, dtype, feasible forced layouts checked off-TPU)
_CASES = (
    ("sum_f32", monoids.sum_, jnp.float32, ("segment", "scan")),
    ("max_f32", monoids.max_, jnp.float32, ("segment", "scan")),
    ("mean_f32", monoids.mean, jnp.float32, ("segment", "scan")),
)

# guarded rows: extra iters to stabilize the median (same as bench_aggregation)
_GUARD = dict(warmup=3, iters=9)


def _values(m, n, d, dtype, rng):
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    if m.name == "mean":
        return (vals, jnp.ones((n,), jnp.int32))
    return vals


def bench_auto_vs_forced(n: int = 1 << 12, d: int = 64, s: int = 128):
    rng = np.random.default_rng(7)
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    on_tpu = jax.default_backend() == "tpu"

    for case, m, dtype, layouts in _CASES:
        vals = _values(m, n, d, dtype, rng)
        if on_tpu:
            layouts = ("kernel",) + tuple(layouts)
        plan = plan_fold(m, vals, segment_ids=segs, num_segments=s)
        auto = jax.jit(lambda v, k, m=m: execute_fold(
            m, v, segment_ids=k, num_segments=s))
        row(f"plan_auto/{case}", time_fn(auto, vals, segs, **_GUARD),
            f"chose={plan.local_tier.kind};predicted_us="
            f"{plan.local_tier.predicted_us:.1f};plan={plan.describe()}")
        for layout in layouts:
            forced = jax.jit(lambda v, k, m=m, layout=layout: execute_fold(
                m, v, segment_ids=k, num_segments=s, layout=layout))
            pred = dict(plan.candidate_us).get(layout, 0.0)
            row(f"plan_forced/{case}/{layout}",
                time_fn(forced, vals, segs, **_GUARD),
                f"predicted_us={pred:.1f}")


def main():
    bench_auto_vs_forced()


if __name__ == "__main__":
    main()
