"""Render the EXPERIMENTS.md tables from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.render_experiments
"""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(name):
    p = os.path.join(RESULTS, name)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def fmt_row(r):
    rf = r["roofline"]
    m = r["memory_analysis"]
    return (f"| {r['arch']} | {r['shape']} | {rf['dominant']} "
            f"| {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} "
            f"| {rf.get('flash_sub_memory_s', rf['memory_s']):.3f} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {100*rf['roofline_fraction']:.2f}% "
            f"| {m.get('total_hbm_bytes', 0)/1e9:.1f} | {r['compile_s']:.0f}s |")


HDR = ("| arch | shape | dominant | compute_s | memory_s | collective_s "
       "| mem_s(flash) | useful | roof% | HBM GB/chip | compile |\n"
       "|---|---|---|---|---|---|---|---|---|---|---|")


def table(name):
    rows = [r for r in load(name) if "roofline" in r]
    out = [HDR]
    for r in rows:
        out.append(fmt_row(r))
    return "\n".join(out)


def delta_table(base_name, opt_name):
    base = {(r["arch"], r["shape"]): r for r in load(base_name) if "roofline" in r}
    opt = {(r["arch"], r["shape"]): r for r in load(opt_name) if "roofline" in r}
    out = ["| arch | shape | bound_s base -> opt | roof% base -> opt | Δbound |",
           "|---|---|---|---|---|"]
    for k in base:
        if k not in opt:
            continue
        b, o = base[k]["roofline"], opt[k]["roofline"]
        d = (b["bound_s"] - o["bound_s"]) / b["bound_s"] * 100
        out.append(f"| {k[0]} | {k[1]} | {b['bound_s']:.3f} -> {o['bound_s']:.3f} "
                   f"| {100*b['roofline_fraction']:.2f}% -> "
                   f"{100*o['roofline_fraction']:.2f}% | {d:+.1f}% |")
    return "\n".join(out)


def main():
    print("### Baseline single-pod (16x16), paper-faithful initial program "
          "(--f32-chains)\n")
    print(table("baseline_single_pod.json"))
    print("\n### Optimized single-pod (16x16), final defaults\n")
    print(table("opt1_single_pod.json"))
    print("\n### Multi-pod (2x16x16 = 512 chips), final defaults\n")
    print(table("opt1_multi_pod.json"))
    print("\n### Baseline -> optimized deltas (bound term)\n")
    print(delta_table("baseline_single_pod.json", "opt1_single_pod.json"))
    print("\n### Hillclimb cells, best variants\n")
    for f in ("hillclimb_llama_seqpar.json", "hillclimb_dsv2_mb8.json"):
        rows = [r for r in load(f) if "roofline" in r]
        if rows:
            print(f"\n{f} ({rows[0]['options']}):\n")
            print(HDR)
            for r in rows:
                print(fmt_row(r))


if __name__ == "__main__":
    main()
