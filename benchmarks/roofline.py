"""Render the §Roofline table from the dry-run JSONs (benchmarks/results/).

  PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--md]

The dry-run sweep itself is `python -m repro.launch.dryrun --arch all
--shape all --out benchmarks/results/baseline_single_pod.json` (and
--multi-pod for the 512-chip pass).
"""
import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(path):
    with open(path) as f:
        return json.load(f)


def render(rows, md=False):
    hdr = ["arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
           "collective_s", "useful", "roofline_frac", "hbm_GB/chip", "compile_s"]
    lines = []
    for r in rows:
        if "roofline" not in r:
            if r.get("skipped"):
                continue
            lines.append([r.get("arch"), r.get("shape"), "-", "ERROR",
                          r.get("error", "")[:40], "", "", "", "", "", ""])
            continue
        rf = r["roofline"]
        lines.append([
            r["arch"], r["shape"], r["mesh"], rf["dominant"],
            f"{rf['compute_s']:.4f}", f"{rf['memory_s']:.4f}",
            f"{rf['collective_s']:.4f}", f"{rf['useful_flops_ratio']:.2f}",
            f"{100 * rf['roofline_fraction']:.2f}%",
            f"{r['memory_analysis'].get('total_hbm_bytes', 0) / 1e9:.1f}",
            f"{r['compile_s']:.1f}",
        ])
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for l in lines:
            print("| " + " | ".join(str(x) for x in l) + " |")
    else:
        print(",".join(hdr))
        for l in lines:
            print(",".join(str(x) for x in l))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--file", default="baseline_single_pod.json")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    render(load(os.path.join(args.results, args.file)), md=args.md)


if __name__ == "__main__":
    main()
