"""Render the §Roofline table from the dry-run JSONs (benchmarks/results/),
and — with ``--calibrate`` — measure the planner's cost-model coefficients.

  PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--md]
  PYTHONPATH=src python -m benchmarks.roofline --calibrate [--quick] [--out P]

The dry-run sweep itself is `python -m repro.launch.dryrun --arch all
--shape all --out benchmarks/results/baseline_single_pod.json` (and
--multi-pod for the 512-chip pass).

``--calibrate`` times each local tier (kernel on TPU, segment-ops, scan,
tree) at three (record count, record bytes) points per (monoid, dtype),
fits ``t(n, b) = t0 + n*us_per_record + n*b*us_per_byte`` through them
(``repro.core.calibration.fit_tier_coeff``), measures per-axis collective
bandwidth when more than one device is visible, and writes the merged
table over the shipped defaults to the calibration cache
(``$REPRO_CALIB`` or ``~/.cache/repro/calib.json``; override with
``--out``).  ``--quick`` shrinks the sizes/monoid set for CI smoke runs.
"""
import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


# ---------------------------------------------------------------------------
# --calibrate: the microbenchmark harness behind the planner's cost model
# ---------------------------------------------------------------------------

def _time_keyed(m, layout, n, d, dtype, num_segments, warmup, iters):
    """Median us of one jitted keyed fold at (n rows, d lanes, dtype)."""
    import jax
    import jax.numpy as jnp
    from repro.core.plan import execute_fold
    from .common import time_fn

    vals = jnp.ones((n, d), dtype)
    seg = jnp.arange(n, dtype=jnp.int32) % num_segments
    fn = jax.jit(lambda v, s: execute_fold(
        m, v, segment_ids=s, num_segments=num_segments, layout=layout))
    return time_fn(fn, vals, seg, warmup=warmup, iters=iters)


def _time_flat(m, layout, n, d, dtype, warmup, iters):
    """Median us of one jitted flat fold (the tree tier)."""
    import jax
    import jax.numpy as jnp
    from repro.core.plan import execute_fold
    from .common import time_fn

    vals = jnp.ones((n, d), dtype)
    fn = jax.jit(lambda v: execute_fold(m, v, layout=layout))
    return time_fn(fn, vals, warmup=warmup, iters=iters)


def _fit_tier(measure, n1, n2, d1, d2, itemsize, warmup, iters):
    """Three-point fit: (n1, b1), (n2, b1), (n2, b2)."""
    from repro.core.calibration import fit_tier_coeff

    t11 = measure(n1, d1)
    t21 = measure(n2, d1)
    t22 = measure(n2, d2)
    return fit_tier_coeff(n1=n1, b1=d1 * itemsize, t11_us=t11,
                          n2=n2, t21_us=t21,
                          b2=d2 * itemsize, t22_us=t22)


def _measure_collectives(warmup, iters):
    """Fit the ICI link model from a psum over all visible devices.

    Single-device processes (CPU CI) skip this and keep the shipped link
    defaults; DCN is never measurable from one host, so it always keeps
    the default until a multi-pod calibration run exists.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.calibration import fit_link_coeff
    from .common import time_fn

    devs = jax.devices()
    if len(devs) < 2:
        return {}
    P_ = len(devs)
    mesh = Mesh(np.array(devs), ("x",))

    def timed_psum(nbytes):
        n = max(nbytes // 4, 1)
        x = jnp.ones((P_, n), jnp.float32)
        fn = jax.jit(jax.shard_map(
            lambda v: jax.lax.psum(v, "x"), mesh=mesh,
            in_specs=(P("x"),), out_specs=P(), check_vma=False))
        return time_fn(fn, x, warmup=warmup, iters=iters)

    b1, b2 = 1 << 12, 1 << 20
    # per-device ring bytes for an allreduce of an nbytes payload
    wire = lambda b: 2.0 * b * (P_ - 1) / P_
    coeff = fit_link_coeff(bytes1=int(wire(b1)), t1_us=timed_psum(b1),
                           bytes2=int(wire(b2)), t2_us=timed_psum(b2),
                           overlap_frac=_measure_overlap(mesh, warmup, iters))
    return {"ici": coeff}


def _measure_overlap(mesh, warmup, iters):
    """Measured overlap coefficient of the visible link: how much of a
    psum's in-flight time a double-buffered microbatch schedule actually
    hides under independent compute (calibration.fit_overlap_frac).

    Drives the planner's async-tier argmin: a runtime whose collectives
    serialize with compute (CPU fake devices) measures ~0 and `auto` will
    keep re-bracketing the fold to cross once at the end.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.calibration import fit_overlap_frac
    from .common import time_fn

    n_mb, dim, depth = 6, 1 << 16, 8

    def compute(mb, w):
        h = mb
        for _ in range(depth):
            h = jnp.tanh(h * w + 0.1)
        return h

    def serial(v, w):
        def body(acc, mb):
            return acc + jax.lax.psum(compute(mb, w), "x"), None
        acc, _ = jax.lax.scan(body, jnp.zeros((dim,), jnp.float32), v[0])
        return acc

    def dbuf(v, w):
        v = v[0]
        def body(carry, mb):
            acc, pending = carry
            crossed = jax.lax.psum(pending, "x")   # independent of compute(mb)
            return (acc + crossed, compute(mb, w)), None
        (acc, pending), _ = jax.lax.scan(
            body, (jnp.zeros((dim,), jnp.float32), compute(v[0], w)), v[1:])
        return acc + jax.lax.psum(pending, "x")

    def compute_only(v, w):
        def body(acc, mb):
            return acc + compute(mb, w), None
        acc, _ = jax.lax.scan(body, jnp.zeros((dim,), jnp.float32), v[0])
        return acc

    P_ = mesh.devices.size
    x = jnp.ones((P_, n_mb, dim), jnp.float32)
    w = jnp.float32(0.5)
    ts = {}
    for name, fn in (("serial", serial), ("dbuf", dbuf),
                     ("compute", compute_only)):
        jitted = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=(P("x"), None), out_specs=P(),
            check_vma=False))
        ts[name] = time_fn(jitted, x, w, warmup=warmup, iters=iters)
    frac = fit_overlap_frac(t_serial_us=ts["serial"], t_dbuf_us=ts["dbuf"],
                            t_compute_us=ts["compute"])
    print(f"calib overlap: serial={ts['serial']:.0f}us dbuf={ts['dbuf']:.0f}us "
          f"compute={ts['compute']:.0f}us -> overlap_frac={frac:.2f}")
    return frac


def calibrate(quick=False, out=None):
    """Measure, fit, merge over defaults, save; returns (Calibration, path)."""
    import jax
    import jax.numpy as jnp
    from repro.core import monoids
    from repro.core.calibration import (CALIB_VERSION, Calibration,
                                        default_calibration, save_calibration)

    backend = jax.default_backend()
    warmup, iters = (1, 3) if quick else (2, 7)
    n1, n2 = (256, 2048) if quick else (1024, 16384)
    d1, d2 = (4, 32) if quick else (4, 64)
    num_segments = 64
    zoo = [(monoids.sum_, "sum", jnp.float32)]
    if not quick:
        zoo += [(monoids.sum_, "sum", jnp.int32),
                (monoids.max_, "max", jnp.float32),
                (monoids.mean, "mean", jnp.float32)]

    default = default_calibration()
    tiers = {k: dict(t) for k, t in default.tiers.items()}

    def record(kind, monoid_name, dtype, coeff):
        table = tiers.setdefault(kind, {})
        key = f"{monoid_name}|{jnp.dtype(dtype).name}"
        table[key] = coeff
        # first measurement of a tier also becomes its generic entry, so
        # unmeasured monoids inherit the measured machine scale
        if default.tiers.get(kind, {}).get("*") is table.get("*"):
            table["*"] = coeff
        print(f"calib {kind:12s} {key:16s} t0={coeff.t0_us:.2f}us "
              f"rec={coeff.us_per_record:.3e} byte={coeff.us_per_byte:.3e}")

    # scan-tier measurements walk n records serially: cap n2 so full mode
    # doesn't spend minutes in lax.scan on CPU
    scan_n2 = min(n2, 4096)
    for m, name, dtype in zoo:
        itemsize = jnp.dtype(dtype).itemsize
        if name in ("sum", "max", "mean"):   # _SEGMENT_OPS members
            record("segment_ops", name, dtype, _fit_tier(
                lambda n, d: _time_keyed(m, "segment", n, d, dtype,
                                         num_segments, warmup, iters),
                n1, n2, d1, d2, itemsize, warmup, iters))
        record("scan", name, dtype, _fit_tier(
            lambda n, d: _time_keyed(m, "scan", n, d, dtype,
                                     num_segments, warmup, iters),
            n1, scan_n2, d1, d2, itemsize, warmup, iters))
        record("tree", name, dtype, _fit_tier(
            lambda n, d: _time_flat(m, "tree", n, d, dtype, warmup, iters),
            n1, n2, d1, d2, itemsize, warmup, iters))
        if backend == "tpu":
            # compiled-kernel rows: only real hardware produces honest
            # kernel coefficients (interpret mode would be 1000x off)
            record("kernel", name, dtype, _fit_tier(
                lambda n, d: _time_keyed(m, "kernel", n, d, dtype,
                                         num_segments, warmup, iters),
                n1, n2, d1, d2, itemsize, warmup, iters))

    collectives = dict(default.collectives)
    measured_links = _measure_collectives(warmup, iters)
    for dom, coeff in measured_links.items():
        collectives[dom] = coeff
        print(f"calib link {dom}: t0={coeff.t0_us:.2f}us "
              f"byte={coeff.us_per_byte:.3e}")

    calib = Calibration(version=CALIB_VERSION, backend=backend,
                        source="measured", tiers=tiers,
                        collectives=collectives)
    path = save_calibration(calib, out)
    print(f"calibration ({backend}, v{CALIB_VERSION}) -> {path}")
    return calib, path


def load(path):
    with open(path) as f:
        return json.load(f)


def render(rows, md=False):
    hdr = ["arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
           "collective_s", "useful", "roofline_frac", "hbm_GB/chip", "compile_s"]
    lines = []
    for r in rows:
        if "roofline" not in r:
            if r.get("skipped"):
                continue
            lines.append([r.get("arch"), r.get("shape"), "-", "ERROR",
                          r.get("error", "")[:40], "", "", "", "", "", ""])
            continue
        rf = r["roofline"]
        lines.append([
            r["arch"], r["shape"], r["mesh"], rf["dominant"],
            f"{rf['compute_s']:.4f}", f"{rf['memory_s']:.4f}",
            f"{rf['collective_s']:.4f}", f"{rf['useful_flops_ratio']:.2f}",
            f"{100 * rf['roofline_fraction']:.2f}%",
            f"{r['memory_analysis'].get('total_hbm_bytes', 0) / 1e9:.1f}",
            f"{r['compile_s']:.1f}",
        ])
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for l in lines:
            print("| " + " | ".join(str(x) for x in l) + " |")
    else:
        print(",".join(hdr))
        for l in lines:
            print(",".join(str(x) for x in l))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--file", default="baseline_single_pod.json")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="measure cost-model coefficients and write the "
                         "calibration cache instead of rendering rooflines")
    ap.add_argument("--quick", action="store_true",
                    help="small sizes / sum-f32 only (CI smoke)")
    ap.add_argument("--out", default=None,
                    help="calibration output path (default: the resolved "
                         "$REPRO_CALIB / ~/.cache/repro/calib.json)")
    args = ap.parse_args(argv)
    if args.calibrate:
        calibrate(quick=args.quick, out=args.out)
        return
    render(load(os.path.join(args.results, args.file)), md=args.md)


if __name__ == "__main__":
    main()
