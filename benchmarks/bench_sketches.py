"""Sketch-monoid throughput (paper §3): CMS / HLL / Bloom stream updates."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monoids
from .common import row, time_fn


def main(n: int = 1 << 15):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))

    cms = monoids.count_min(4, 2048)
    fn = jax.jit(lambda t: monoids.cms_update_batch(cms.identity(), t))
    us = time_fn(fn, toks)
    row("sketch/cms_update", us, f"tokens={n};Mtok_s={n/us:.1f}")

    hll = monoids.hyperloglog(12)
    fn = jax.jit(lambda t: monoids.hll_update_batch(hll.identity(), t))
    us = time_fn(fn, toks)
    est = float(hll.extract(fn(toks)))
    true = len(np.unique(np.asarray(toks)))
    row("sketch/hll_update", us,
        f"est={est:.0f};true={true};err={abs(est-true)/true*100:.1f}%")

    blm = monoids.bloom_filter(1 << 16)
    @jax.jit
    def bloom_batch(t):
        filt = blm.identity()
        nb = filt.shape[-1]
        for s in range(4):
            filt = filt.at[monoids._uhash(t, s) % nb].set(1)
        return filt
    us = time_fn(bloom_batch, toks)
    row("sketch/bloom_update", us, f"bits={1<<16}")


if __name__ == "__main__":
    main()
