"""Aggregation-layer benches: fold strategies, grad accumulation, metric
monoids, gradient compression — each 'derived' column reports the wire/byte
quantity the paper's principle reduces."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execute_fold, monoids, plan_fold
from repro.core.aggregation import allreduce_wire_bytes, tree_bytes
from repro.kernels import ops as kops
from repro.optim.compress import (compressed_bytes, init_error_state,
                                  int8_compress, topk_compress)
from .common import row, time_fn


def bench_fold_strategies(n: int = 4096, d: int = 256):
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    t = jax.jit(lambda x: execute_fold(monoids.sum_, x, layout="tree"))
    s = jax.jit(lambda x: execute_fold(monoids.sum_, x, layout="scan"))
    row("fold/tree(log-depth)", time_fn(t, xs), f"depth={int(np.ceil(np.log2(n)))}")
    row("fold/scan(in-mapper)", time_fn(s, xs), f"depth={n};live_valsB={d*4}")


def bench_grad_accum(mb: int = 8, dim: int = 1 << 16):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    data = jnp.asarray(rng.normal(size=(mb, 32, dim)).astype(np.float32) / dim**0.5)

    def lg(p, b):
        l, g = jax.value_and_grad(lambda q: jnp.mean(jnp.square(b @ q)))(p)
        return {"loss": l}, g

    fn = jax.jit(lambda p, d: execute_fold(
        monoids.sum_, d, map_fn=lambda b: lg(p, b), layout="scan"))
    us = time_fn(fn, w, data)
    row("grad_accum/scan_fold", us,
        f"microbatches={mb};materialized_gradsB={dim*4}(1 copy, not {mb})")


def bench_planner_tiers(n: int = 1 << 12, d: int = 64, s: int = 128):
    """The planner's keyed-fold tiers vs the pre-refactor direct kernel call.

    segment_fold/planner_kernel must stay within noise of
    segment_fold/direct_pallas — the planner adds trace-time dispatch only.
    """
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    segs = jnp.asarray(rng.integers(0, s, n).astype(np.int32))

    plan = plan_fold(monoids.sum_, vals, segment_ids=segs, num_segments=s,
                     layout="kernel")
    direct = lambda v, k: kops.segment_fold(v, k, s)
    via_kernel = jax.jit(lambda v, k: execute_fold(
        monoids.sum_, v, segment_ids=k, num_segments=s, layout="kernel"))
    via_seg = jax.jit(lambda v, k: execute_fold(
        monoids.sum_, v, segment_ids=k, num_segments=s, layout="segment"))
    # guarded rows (CI --compare gate): extra iters to stabilize the median
    # against interpret-mode jitter
    guard = dict(warmup=3, iters=9)
    row("segment_fold/direct_pallas", time_fn(direct, vals, segs, **guard),
        f"n={n};keys={s}")
    row("segment_fold/planner_kernel", time_fn(via_kernel, vals, segs, **guard),
        f"plan={plan.describe()}")
    row("segment_fold/planner_segment_ops",
        time_fn(via_seg, vals, segs, **guard), f"tableB={plan.out_bytes}")

    mean_direct = lambda v, k: kops.mean_by_key(v, k, s)
    mean_planner = jax.jit(lambda v, k: jax.vmap(monoids.mean.extract)(
        execute_fold(monoids.mean, (v, jnp.ones((n,), jnp.int32)),
                     segment_ids=k, num_segments=s, layout="kernel")))
    row("mean_by_key/direct_pallas", time_fn(mean_direct, vals, segs, **guard),
        f"n={n};keys={s}")
    row("mean_by_key/planner_kernel", time_fn(mean_planner, vals, segs, **guard),
        "extract(sum/count) via planner")


def bench_metric_monoid_fusion(n_stats: int = 6):
    """Product monoid: one combine for many stats vs one combine each."""
    vals = {f"s{i}": monoids.mean.lift(jnp.float32(i)) for i in range(n_stats)}
    prod = monoids.product(**{f"s{i}": monoids.mean for i in range(n_stats)})
    one = jax.jit(lambda a, b: prod.combine(a, b))
    us = time_fn(one, vals, vals)
    nbytes = tree_bytes(vals)
    row("metrics/product_monoid", us,
        f"collectives=1;payloadB={nbytes};vs={n_stats}_separate_psums")


def bench_hierarchical_allreduce_model(nbytes: int = 1 << 30):
    """Wire-byte model of flat vs hierarchical cross-pod gradient reduction
    (2 pods x 256 chips, ICI ring inside the pod, DCN across)."""
    flat_dcn = allreduce_wire_bytes(nbytes, 512, algorithm="ring")
    hier_dcn = allreduce_wire_bytes(nbytes // 256, 2, algorithm="ring")
    row("grad_reduce/flat_512way", 0.0, f"dcn_bytes={flat_dcn}")
    row("grad_reduce/hierarchical", 0.0,
        f"dcn_bytes={hier_dcn};reduction={flat_dcn/max(hier_dcn,1):.0f}x")


def bench_compression(dim: int = 1 << 20):
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))}
    err = init_error_state(g)
    tk = jax.jit(lambda g, e: topk_compress(g, e, ratio=0.01))
    i8 = jax.jit(int8_compress)
    us_tk = time_fn(tk, g, err)
    us_i8 = time_fn(i8, g, err)
    ctk, _ = tk(g, err)
    ci8, _ = i8(g, err)
    row("compress/topk_ef(1%)", us_tk,
        f"bytes={compressed_bytes(ctk)};ratio={dim*4/compressed_bytes(ctk):.1f}x")
    row("compress/int8_ef", us_i8,
        f"bytes={compressed_bytes(ci8)};ratio={dim*4/compressed_bytes(ci8):.1f}x")


def main():
    bench_fold_strategies()
    bench_planner_tiers()
    bench_grad_accum()
    bench_metric_monoid_fusion()
    bench_hierarchical_allreduce_model()
    bench_compression()


if __name__ == "__main__":
    main()
