"""Step builders: jit-compiled train / prefill / decode steps with explicit
in/out shardings for a production mesh.

Everything here is dry-run-compatible: abstract params (ShapeDtypeStructs)
flow through the same code paths as real arrays, so ``.lower().compile()``
exercises exactly the program that would run on hardware.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import monoids
from ..core.plan import execute_fold
from ..models import (ModelConfig, RunCtx, decode_step, forward, init_cache,
                      loss_fn, param_axes, param_shapes, unembed)
from ..optim import OptConfig, adamw_update, opt_state_shapes
from ..dist import sharding as shd
from ..configs import ShapeCell, context_spec, input_specs

Pytree = Any


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

trim_rules = shd.trim_rules  # canonical definition lives in dist.sharding


def batch_sharding(mesh: Mesh, rules, dim0: Optional[int] = None) -> NamedSharding:
    """Batch-dim sharding, dropping axes that don't divide dim0 (e.g. B=1)."""
    ax = rules.get("batch")
    axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
    if dim0 is not None:
        kept, total = [], 1
        for a in axes:
            if dim0 % (total * mesh.shape[a]) == 0:
                kept.append(a)
                total *= mesh.shape[a]
        axes = tuple(kept)
    spec = P(axes if len(axes) > 1 else (axes[0] if axes else None))
    return NamedSharding(mesh, spec)


def data_shardings(cfg: ModelConfig, mesh: Mesh, rules, specs: Dict) -> Dict:
    """Shardings for the data inputs (tokens/labels/context): batch-sharded."""
    return {k: batch_sharding(mesh, rules, v.shape[0]) for k, v in specs.items()}


_CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "xk": ("batch", None, "kv_heads", None),
    "xv": ("batch", None, "kv_heads", None),
    "lat_c": ("batch", "kv_seq", None),
    "lat_r": ("batch", "kv_seq", None),
    "ssm_h": ("batch", "mlp", None),
    "ssm_conv": ("batch", None, "mlp"),
    "ml_C": ("batch", "heads", None, None),
    "ml_n": ("batch", "heads", None),
    "sl_h": ("batch", "heads", None),
    "sl_c": ("batch", "heads", None),
    "pos": (),
}


def cache_shardings(cache_shapes: Pytree, mesh: Mesh, rules) -> Pytree:
    """NamedSharding tree for a decode cache, by leaf name (see _CACHE_AXES).

    Leaves under the stacked 'layers' subtree get a leading None (period dim).
    """
    def one(path, leaf):
        name = None
        stacked = False
        for entry in path:
            key = getattr(entry, "key", None)
            if key == "layers":
                stacked = True
            if isinstance(key, str):
                name = key
        names = _CACHE_AXES.get(name, ())
        names = ((None,) if stacked else ()) + tuple(names)
        spec = shd.spec_for(names, rules, mesh, shape=leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def tree_of(val, tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda _: val, tree)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BuiltStep:
    """A jit'd step + everything needed to call or dry-run it."""
    fn: Any                      # the jit-wrapped callable
    abstract_args: Tuple         # ShapeDtypeStruct pytrees for .lower()
    in_shardings: Tuple
    out_shardings: Any
    mesh: Mesh
    rules: Dict[str, Any]


def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell, *,
                    opt: OptConfig = OptConfig(),
                    ctx: Optional[RunCtx] = None,
                    num_microbatches: int = 1,
                    lossy: Optional[str] = None,
                    rules: Optional[Dict[str, Any]] = None,
                    donate: bool = True) -> BuiltStep:
    """Build the jit'd train step for (arch x train shape) on a mesh.

    num_microbatches > 1 folds gradients over microbatches with a lax.scan
    carry — the paper's in-mapper combining (Algorithm 4) applied to the
    gradient Sum monoid; nothing per-microbatch is materialized.

    ``lossy`` (e.g. ``"topk:0.01"`` / ``"blocktopk:0.001"`` / ``"int8"``)
    annotates the gradient fold with the compressor a cross-pod (DCN) wire
    would apply (optim/compress.py): the update consumes the compressed
    round-trip of the folded gradients, and the error-feedback residual is
    carried as ``opt_state["ef"]`` — resumable fold state that checkpoints
    with the optimizer, so the applied-update sum converges to the true
    gradient sum across steps.  Under this jit step the numerics are
    identical to what the planner's lossy DCN crossing applies under
    shard_map; the wire-byte savings themselves are the planner's story
    (core/plan.py, benchmarks/bench_overlap.py).
    """
    rules = trim_rules(rules or shd.TRAIN_RULES, mesh)
    ctx = ctx or RunCtx(mesh=mesh)
    if ctx.mesh is None:
        ctx = dataclasses.replace(ctx, mesh=mesh)
    spec = None
    if lossy is not None:
        from ..optim.compress import LossySpec
        spec = LossySpec.parse(lossy)

    def train_step(params, opt_state, batch):
        with shd.use_rules(mesh, rules):
            def one_loss(p, b):
                return loss_fn(p, cfg, b, ctx)

            if num_microbatches > 1:
                def reshape_mb(x):
                    B = x.shape[0]
                    mb = B // num_microbatches
                    return x.reshape((num_microbatches, mb) + x.shape[1:])

                mbatch = jax.tree_util.tree_map(reshape_mb, batch)
                grad_fn = jax.value_and_grad(one_loss, has_aux=True)

                def one_grad(mb):
                    (_, metrics), grads = grad_fn(params, mb)
                    return grads, metrics

                # in-mapper combining over microbatches: the planner's scan
                # tier folds the gradient Sum monoid without materializing
                # per-microbatch grads (paper, Algorithm 4)
                grads, metrics = execute_fold(monoids.sum_, mbatch,
                                              map_fn=one_grad, layout="scan")
                gscale = 1.0 / num_microbatches
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    one_loss, has_aux=True)(params, batch)
                gscale = 1.0
            if spec is not None:
                comp, new_ef = spec.compress(grads, opt_state["ef"])
                grads = spec.decompress(comp, grads)
                opt_state = {k: v for k, v in opt_state.items() if k != "ef"}
            new_params, new_opt, om = adamw_update(grads, opt_state, opt,
                                                   grad_scale=gscale)
            if spec is not None:
                new_opt["ef"] = new_ef
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = metrics["loss_sum"] / jnp.maximum(metrics["tokens"], 1.0)
        return new_params, new_opt, metrics

    pshapes = param_shapes(cfg)
    paxes = param_axes(cfg)
    pshard = shd.param_shardings(pshapes, paxes, mesh, rules)
    oshapes = opt_state_shapes(pshapes, with_ef=spec is not None)
    oshard = {"step": replicated(mesh),
              "m": pshard, "v": pshard,
              "master": pshard}
    if spec is not None:
        oshard["ef"] = pshard
    specs = input_specs(cfg, shape)
    bshard = data_shardings(cfg, mesh, rules, specs)
    mshapes = jax.eval_shape(train_step, pshapes, oshapes, specs)[2]
    out_shardings = (pshard, oshard, tree_of(replicated(mesh), mshapes))
    fn = jax.jit(train_step,
                 in_shardings=(pshard, oshard, bshard),
                 out_shardings=out_shardings,
                 donate_argnums=(0, 1) if donate else ())
    return BuiltStep(fn=fn, abstract_args=(pshapes, oshapes, specs),
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=out_shardings, mesh=mesh, rules=rules)


# ---------------------------------------------------------------------------
# prefill / decode steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell, *,
                      ctx: Optional[RunCtx] = None,
                      rules: Optional[Dict[str, Any]] = None) -> BuiltStep:
    """Inference prefill: full-sequence forward + last-token logits."""
    rules = trim_rules(rules or shd.SERVE_RULES, mesh)
    ctx = ctx or RunCtx(mesh=mesh)
    if ctx.mesh is None:
        ctx = dataclasses.replace(ctx, mesh=mesh)

    def prefill(params, batch):
        with shd.use_rules(mesh, rules):
            h, _ = forward(params, cfg, batch["tokens"],
                           context=batch.get("context"), ctx=ctx)
            logits = unembed(params, cfg, h[:, -1:])
        return logits

    pshapes = param_shapes(cfg)
    pshard = shd.param_shardings(pshapes, param_axes(cfg), mesh, rules)
    specs = input_specs(cfg, shape)
    bshard = data_shardings(cfg, mesh, rules, specs)
    oshard = batch_sharding(mesh, rules, shape.global_batch)
    fn = jax.jit(prefill, in_shardings=(pshard, bshard),
                 out_shardings=oshard)
    return BuiltStep(fn=fn, abstract_args=(pshapes, specs),
                     in_shardings=(pshard, bshard),
                     out_shardings=oshard,
                     mesh=mesh, rules=rules)


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell, *,
                     ctx: Optional[RunCtx] = None,
                     rules: Optional[Dict[str, Any]] = None,
                     donate: bool = True) -> BuiltStep:
    """One-token decode against a seq_len KV cache (the serve_step).

    For long_500k cells ``ctx.decode_impl='flash'`` runs the sequence-sharded
    flash-decode path (AttnState monoid over the 'model' axis).
    """
    rules = trim_rules(rules or shd.SERVE_RULES, mesh)
    ctx = ctx or RunCtx(mesh=mesh)
    if ctx.mesh is None:
        ctx = dataclasses.replace(ctx, mesh=mesh)

    def serve_step(params, cache, tokens):
        with shd.use_rules(mesh, rules):
            logits, new_cache = decode_step(params, cfg, cache, tokens, ctx=ctx)
        return logits, new_cache

    pshapes = param_shapes(cfg)
    pshard = shd.param_shardings(pshapes, param_axes(cfg), mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    ctx_in = context_spec(cfg, B)
    cache_shapes = jax.eval_shape(
        partial(init_cache, cfg=cfg, batch=B, max_seq=S, ctx=ctx),
        pshapes, context=ctx_in)
    cshard = cache_shardings(cache_shapes, mesh, rules)
    tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tshard = batch_sharding(mesh, rules, B)
    out_shardings = (tshard, cshard)
    fn = jax.jit(serve_step,
                 in_shardings=(pshard, cshard, tshard),
                 out_shardings=out_shardings,
                 donate_argnums=(1,) if donate else ())
    return BuiltStep(fn=fn, abstract_args=(pshapes, cache_shapes, tok_spec),
                     in_shardings=(pshard, cshard, tshard),
                     out_shardings=out_shardings, mesh=mesh, rules=rules)


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeCell, **kw) -> BuiltStep:
    """Dispatch on the cell kind."""
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, **kw)
    if shape.kind == "decode":
        return make_decode_step(cfg, mesh, shape, **kw)
    raise ValueError(shape.kind)
