"""Serving driver: batched prefill + decode with KV caches on the host mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --batch 4 \
      --prompt-len 32 --gen 32 [--full]

The production-mesh serving step (256/512 chips, sequence-sharded KV for
long contexts) is the same `make_decode_step` exercised by the dry-run;
this driver runs it for real at host scale with smoke configs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ShapeCell, context_spec, get_config
from ..models import init_cache, init_params
from ..optim import OptConfig  # noqa: F401  (parity of public surface)
from .mesh import make_host_mesh
from .steps import make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=not args.full)
    mesh = make_host_mesh(model=args.model_parallel)
    B = args.batch
    max_seq = args.prompt_len + args.gen
    shape = ShapeCell("serve", "decode", max_seq, B)
    built = make_decode_step(cfg, mesh, shape, donate=False)

    key = jax.random.PRNGKey(0)
    params, _ = init_params(cfg, key)
    params = jax.device_put(params, built.in_shardings[0])
    spec = context_spec(cfg, B)
    context = None if spec is None else jax.random.normal(key, spec.shape, cfg.dtype)
    cache = init_cache(params, cfg, B, max_seq, context=context)
    cache = jax.device_put(cache, built.in_shardings[1])

    prompt = jax.random.randint(key, (B, args.prompt_len), 1, cfg.vocab_size)
    t0 = time.perf_counter()
    for i in range(args.prompt_len):
        logits, cache = built.fn(params, cache, prompt[:, i:i + 1])
    prefill_s = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = built.fn(params, cache, out[-1])
        key, sub = jax.random.split(key)
        out.append(jax.random.categorical(sub, logits[:, -1], axis=-1)
                   [:, None].astype(jnp.int32))
    decode_s = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={B} prefill={args.prompt_len} gen={args.gen}")
    print(f"prefill {B*args.prompt_len/prefill_s:.0f} tok/s | "
          f"decode {B*(args.gen-1)/max(decode_s,1e-9):.0f} tok/s")
    return 0


if __name__ == "__main__":
    main()
