"""Batched serving driver: the decode step as a keyed MapReduce pass.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --requests 8 --max-batch 4 --min-prompt 8 --max-prompt 32 --gen 16

Concurrent requests are batched by :class:`repro.runtime.RequestBatcher`
(max-batch-size / max-wait policies) and decoded together against one KV
cache.  The serving-side aggregation — per-request logprob sums, generated
token counts, and the stop-condition reduction — is ONE planner-lowered
keyed fold per decode step (``request slot == segment id``), not a
per-request Python loop: the same way the train step amortizes the shuffle
with a combiner, the serve step amortizes both the kernel launch and the
aggregation across the whole batch.  Requests have different prompt lengths
and different generation budgets, so every fold runs ragged: a
``valid_mask`` marks the rows (slots) that are actively generating this
step, and masked rows contribute the monoid identity (core/plan.py).

The production-mesh serving step (256/512 chips, sequence-sharded KV for
long contexts) is the same `make_decode_step` exercised by the dry-run;
this driver runs it for real at host scale with smoke configs.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ShapeCell, context_spec, get_config
from ..core import monoids
from ..core.plan import Plan, execute_fold, plan_fold
from ..models import init_cache, init_params
from ..runtime.batcher import DecodeBatch, RequestBatcher
from .mesh import make_host_mesh
from .steps import BuiltStep, make_decode_step

# columns of the per-request metrics table — ONE additive fold carries all
# three: sum of sampled-token logprobs, count of generated tokens, and the
# stop condition as a summed indicator (eos_hits > 0 <=> OR of eos hits)
METRIC_COLS = ("logprob_sum", "tokens", "eos_hits")


def decode_metrics_init(num_slots: int) -> jnp.ndarray:
    """The identity table: (num_slots, len(METRIC_COLS)) float32 zeros."""
    return jnp.zeros((num_slots, len(METRIC_COLS)), jnp.float32)


def decode_metrics_plan(batch_rows: int, num_slots: int) -> Plan:
    """The plan of ONE decode step's per-request aggregation (no FLOPs).

    This is the contract the serving path is built on: B concurrent
    requests aggregate through a single keyed, masked fold — inspect the
    plan to see one local tier, not B of them.
    """
    return plan_fold(
        monoids.sum_,
        jax.ShapeDtypeStruct((batch_rows, len(METRIC_COLS)), jnp.float32),
        segment_ids=jax.ShapeDtypeStruct((batch_rows,), jnp.int32),
        num_segments=num_slots,
        valid_mask=jax.ShapeDtypeStruct((batch_rows,), jnp.bool_))


@functools.partial(jax.jit, static_argnames=("num_slots", "eos_id"))
def decode_metrics_step(table: jnp.ndarray, logits: jnp.ndarray,
                        sampled: jnp.ndarray, slot_ids: jnp.ndarray,
                        active: jnp.ndarray, *, num_slots: int,
                        eos_id: int) -> jnp.ndarray:
    """Fold one decode step's per-request aggregates into the running table.

    logits: (B, V) last-position logits; sampled: (B,) sampled token ids;
    slot_ids: (B,) request slot per row (segment ids); active: (B,) bool —
    rows still generating this step.  The whole batch reduces in ONE
    planner-lowered keyed fold; inactive/empty slots are masked to the
    identity, and the running table rides in as ``init`` (the fold across
    steps is the same monoid, re-bracketed — the paper's point).
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_logp = jnp.take_along_axis(logp, sampled[:, None], axis=-1)[:, 0]
    rows = jnp.stack(
        [tok_logp, jnp.ones_like(tok_logp),
         (sampled == eos_id).astype(jnp.float32)], axis=-1)
    return execute_fold(monoids.sum_, rows, segment_ids=slot_ids,
                        num_segments=num_slots, valid_mask=active,
                        init=table)


def extract_metrics(table: jnp.ndarray) -> Dict[str, np.ndarray]:
    """Read the metrics table out into per-slot host arrays."""
    t = np.asarray(table)
    return {
        "logprob_sum": t[:, 0],
        "tokens": t[:, 1].astype(np.int64),
        "stopped": t[:, 2] > 0,       # summed eos indicator == OR
    }


@dataclasses.dataclass
class BatchResult:
    """Outcome of decoding one flushed batch."""

    batch: DecodeBatch
    tokens: np.ndarray            # (num_slots, max_new) generated ids (0-padded)
    metrics: Dict[str, np.ndarray]
    decode_steps: int
    prefill_s: float
    decode_s: float


def run_batched_decode(built: BuiltStep, params, cache, batch: DecodeBatch, *,
                       eos_id: int = 0, pad_id: int = 0,
                       temperature: float = 0.0,
                       key: Optional[jax.Array] = None,
                       max_steps: Optional[int] = None) -> BatchResult:
    """Decode one ragged batch to completion with per-step keyed-fold metrics.

    The loop advances ALL slots one position per step.  A slot is forced
    from its prompt while the position is inside it, then samples until it
    hits ``eos_id``, exhausts its ``max_new_tokens`` budget, or the batch
    hits ``max_steps``.  Per-step aggregation is one masked keyed fold —
    see :func:`decode_metrics_step`.
    """
    toks, lengths, _ = batch.pack(pad_id=pad_id)
    S, L = toks.shape
    slot_ids = jnp.asarray(batch.segment_ids)
    lengths_j = jnp.asarray(np.maximum(lengths, 1))   # empty slots idle at 1
    max_new = jnp.asarray(batch.max_new())
    budget = int(batch.max_new().max(initial=0))
    total_steps = (L - 1) + budget if max_steps is None \
        else min((L - 1) + budget, max_steps)

    table = decode_metrics_init(S)
    gen = np.zeros((S, max(budget, 1)), np.int64)
    n_new = jnp.zeros((S,), jnp.int32)
    done = jnp.asarray(~batch.slot_valid)             # empty slots start done
    toks_j = jnp.asarray(toks)
    cur = toks_j[:, 0:1]
    if key is None:
        key = jax.random.PRNGKey(0)

    t0 = time.perf_counter()
    prefill_s = None
    decode_steps = 0
    for p in range(total_steps):
        logits, cache = built.fn(params, cache, cur)
        last = logits[:, -1]
        if temperature > 0:
            key, sub = jax.random.split(key)
            sampled = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            sampled = jnp.argmax(last, axis=-1)
        sampled = sampled.astype(jnp.int32)
        in_prompt = (p + 1) < lengths_j               # next pos still forced
        emitting = (~in_prompt) & (~done) & (n_new < max_new)
        # ONE keyed fold for the whole batch: logprob sums + token counts +
        # stop hits, ragged over the active slots
        table = decode_metrics_step(table, last, sampled, slot_ids, emitting,
                                    num_slots=S, eos_id=eos_id)
        n_next = n_new + emitting.astype(jnp.int32)
        done = done | (emitting & (sampled == eos_id)) | (n_next >= max_new)
        # one host sync per step for the token buffer + stop poll
        emit_np, idx_np, samp_np, all_done = jax.device_get(
            (emitting, n_new, sampled, jnp.all(done)))
        if emit_np.any():
            if prefill_s is None:     # first emission anywhere: decode begins
                prefill_s = time.perf_counter() - t0
            gen[emit_np, idx_np[emit_np]] = samp_np[emit_np]
            decode_steps += 1
        n_new = n_next
        forced = toks_j[:, min(p + 1, L - 1)]
        cur = jnp.where(in_prompt, forced, sampled)[:, None]
        if bool(all_done):
            break
    total_s = time.perf_counter() - t0
    if prefill_s is None:
        prefill_s = total_s
    return BatchResult(batch=batch, tokens=gen, metrics=extract_metrics(table),
                       decode_steps=decode_steps, prefill_s=prefill_s,
                       decode_s=max(total_s - prefill_s, 1e-9))


def build_serve_step(arch: str, *, max_batch: int, max_seq: int,
                     model_parallel: int = 1, full: bool = False,
                     seed: int = 0):
    """(cfg, built, params, make_cache): everything one serving loop needs.

    ``make_cache()`` returns a fresh sharded KV cache — one per flushed
    batch; params load once and are reused across batches.
    """
    cfg = get_config(arch, smoke=not full)
    mesh = make_host_mesh(model=model_parallel)
    shape = ShapeCell("serve", "decode", max_seq, max_batch)
    built = make_decode_step(cfg, mesh, shape, donate=False)
    key = jax.random.PRNGKey(seed)
    params, _ = init_params(cfg, key)
    params = jax.device_put(params, built.in_shardings[0])
    spec = context_spec(cfg, max_batch)
    context = None if spec is None else jax.random.normal(key, spec.shape,
                                                          cfg.dtype)

    def make_cache():
        cache = init_cache(params, cfg, max_batch, max_seq, context=context)
        return jax.device_put(cache, built.in_shardings[1])

    return cfg, built, params, make_cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait-ms", type=float, default=0.0)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg, built, params, make_cache = build_serve_step(
        args.arch, max_batch=args.max_batch,
        max_seq=args.max_prompt + args.gen,
        model_parallel=args.model_parallel, full=args.full)

    rng = np.random.default_rng(0)
    batcher = RequestBatcher(max_batch_size=args.max_batch,
                             max_wait_s=args.max_wait_ms / 1e3)
    for _ in range(args.requests):
        plen = int(rng.integers(args.min_prompt, args.max_prompt + 1))
        prompt = rng.integers(1, cfg.vocab_size, plen).tolist()
        batcher.submit(prompt, max_new_tokens=args.gen)

    plan = decode_metrics_plan(args.max_batch, args.max_batch)
    print(f"arch={cfg.name} requests={args.requests} "
          f"max_batch={args.max_batch} gen<={args.gen}")
    print(f"per-step aggregation plan: {plan.describe()}")

    key = jax.random.PRNGKey(1)
    served = new_tokens = 0
    t0 = time.perf_counter()
    while len(batcher):
        if not batcher.ready():
            # trailing partial batch: honor the max-wait latency bound
            # before flushing it (full batches flush immediately)
            time.sleep(max(args.max_wait_ms, 0.0) / 1e3)
        batch = batcher.flush(force=True)
        key, sub = jax.random.split(key)
        res = run_batched_decode(built, params, make_cache(), batch,
                                 eos_id=0, temperature=args.temperature,
                                 key=sub)
        served += len(batch)
        toks = res.metrics["tokens"][batch.slot_valid]
        new_tokens += int(toks.sum())
        print(f"  batch of {len(batch)}: prompts="
              f"{batch.lengths()[batch.slot_valid].tolist()} "
              f"generated={toks.tolist()} "
              f"logprob_sum={np.round(res.metrics['logprob_sum'][batch.slot_valid], 2).tolist()} "
              f"({res.decode_steps} decode steps, "
              f"{int(toks.sum()) / res.decode_s:.0f} tok/s)")
    wall = time.perf_counter() - t0
    st = batcher.stats
    print(f"served {served} requests, {new_tokens} tokens in {wall:.2f}s "
          f"({new_tokens / wall:.0f} tok/s) | batches={st.flushed_batches} "
          f"fill={st.fill_rate(args.max_batch):.2f}")
    return 0


if __name__ == "__main__":
    main()
