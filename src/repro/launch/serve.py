"""Continuous-batching serving driver: decode as a rolling keyed MapReduce.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \\
      --requests 8 --slots 4 --buckets 8,16 --gen 8 --rate 50

This module wires the model substrate (configs/models/mesh) into the
model-agnostic :class:`repro.runtime.ContinuousEngine` and hosts the CLI.
Requests arrive on a Poisson trace, queue FIFO in the engine's admission
queue, and are admitted into *rolling slots*: a slot freed by an EOS or an
exhausted budget is handed to the next waiting request mid-decode.  The
per-request aggregation — logprob sums, generated token counts, and the
stop-condition reduction — is ONE planner-lowered keyed fold per decode
step (``request slot == segment id``) over whatever population currently
occupies the slots, with a ``valid_mask`` for the empty ones: the same way
the train step amortizes the shuffle with a combiner, the serve step
amortizes both the kernel launch and the aggregation across the rolling
batch.  Compilation is bounded by the prefill bucket ladder
(:class:`repro.runtime.ServeConfig`), so slot churn never recompiles.

The stable import surface for applications is :mod:`repro.serving`; the
production-mesh serving step (sequence-sharded KV for long contexts) is
still exercised by the dry-run via ``launch/steps.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ShapeCell, context_spec, get_config
from ..data.windows import WindowedMetrics
from ..dist import sharding as shd
from ..models import (RunCtx, decode_step, init_cache, init_params,
                      param_axes, param_shapes, positional_cache)
from ..runtime.batcher import DecodeBatch
from ..runtime.engine import (ContinuousEngine, EngineBackend, ServeConfig,
                              decode_metrics_init, decode_metrics_plan,
                              decode_metrics_step, extract_metrics,
                              METRIC_COLS)
from .mesh import make_host_mesh
from .steps import make_decode_step

__all__ = [
    "METRIC_COLS", "decode_metrics_init", "decode_metrics_plan",
    "decode_metrics_step", "extract_metrics", "ServeConfig", "BatchResult",
    "build_engine", "build_serve_step", "run_batched_decode", "main",
]


def build_engine(config: ServeConfig, *,
                 clock=time.perf_counter) -> ContinuousEngine:
    """A :class:`ContinuousEngine` over the real model substrate.

    Builds params + mesh for ``config.arch`` and hands the engine a
    traceable one-token decode (the same ``decode_step`` the dry-run
    lowers) plus a cache constructor with per-slot positions for the
    rolling cache.  Everything shape-dependent — slot count, prefill
    bucket ladder, generation budget — comes from ``config``.
    """
    cfg = get_config(config.arch, smoke=not config.full)
    if context_spec(cfg, 1) is not None:
        raise NotImplementedError(
            f"{config.arch}: context-conditioned archs (audio/vision) are "
            f"not supported by the continuous engine yet")
    mesh = make_host_mesh(model=config.model_parallel)
    rules = shd.trim_rules(shd.SERVE_RULES, mesh)
    ctx = RunCtx(mesh=mesh)
    key = jax.random.PRNGKey(config.seed)
    params, _ = init_params(cfg, key)
    if config.model_parallel > 1:
        pshard = shd.param_shardings(param_shapes(cfg), param_axes(cfg),
                                     mesh, rules)
        params = jax.device_put(params, pshard)

    def decode(p, cache, cur):
        with shd.use_rules(mesh, rules):
            logits, cache = decode_step(p, cfg, cache, cur, ctx=ctx)
        return logits[:, -1].astype(jnp.float32), cache

    def make_cache(batch: int, pos_per_slot: bool):
        return init_cache(params, cfg, batch, config.max_seq, ctx=ctx,
                          pos_per_slot=pos_per_slot)

    def place(tree):
        # commit with the sharding the mesh-aware jitted programs emit, so
        # the engine's first write_slot call compiles once, not twice
        return jax.device_put(
            tree, jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))

    backend = EngineBackend(decode=decode, init_cache=make_cache,
                            params=params, vocab_size=cfg.vocab_size,
                            # prefix KV sharing needs position-indexed cache
                            # rows; recurrent/cross-attn substrates opt out
                            prefix_sharing=positional_cache(cfg),
                            place=place)
    return ContinuousEngine(backend, config, clock=clock)


def build_serve_step(config: ServeConfig):
    """(cfg, built, params, make_cache) for the FIXED-shape serve step.

    The pre-engine API, now driven by the same :class:`ServeConfig`: one
    jitted ``(num_slots, 1)`` decode step against a ``max_seq`` cache with
    explicit mesh shardings.  The dry-run and the step-level benchmark rows
    still exercise this; request-level serving goes through
    :func:`build_engine`.
    """
    cfg = get_config(config.arch, smoke=not config.full)
    mesh = make_host_mesh(model=config.model_parallel)
    shape = ShapeCell("serve", "decode", config.max_seq, config.num_slots)
    built = make_decode_step(cfg, mesh, shape, donate=False)
    key = jax.random.PRNGKey(config.seed)
    params, _ = init_params(cfg, key)
    params = jax.device_put(params, built.in_shardings[0])
    spec = context_spec(cfg, config.num_slots)
    context = None if spec is None else jax.random.normal(key, spec.shape,
                                                          cfg.dtype)

    def make_cache():
        cache = init_cache(params, cfg, config.num_slots, config.max_seq,
                           context=context)
        return jax.device_put(cache, built.in_shardings[1])

    return cfg, built, params, make_cache


@dataclasses.dataclass
class BatchResult:
    """Outcome of decoding one flushed batch (legacy shape, kept for the
    deprecated :func:`run_batched_decode` shim)."""

    batch: DecodeBatch
    tokens: np.ndarray            # (num_slots, max_new) generated ids (0-padded)
    metrics: Dict[str, np.ndarray]
    decode_steps: int
    prefill_s: float
    decode_s: float


def run_batched_decode(engine: ContinuousEngine, batch: DecodeBatch, *,
                       max_steps: Optional[int] = None) -> BatchResult:
    """DEPRECATED: decode one fixed batch to completion through the engine.

    The PR-3 API decoded a flushed :class:`DecodeBatch` as a unit; the
    engine subsumes it — this shim submits the batch's requests, drains the
    engine, and reassembles a :class:`BatchResult` (slot ``i`` of the
    result is request ``i`` of the batch).  Use
    :meth:`ContinuousEngine.submit` / :meth:`~ContinuousEngine.run`
    directly: the engine overlaps requests instead of waiting for the
    slowest one.
    """
    warnings.warn(
        "run_batched_decode is deprecated: submit requests to "
        "repro.serving.ContinuousEngine directly (continuous batching "
        "replaces batch-to-completion decode)", DeprecationWarning,
        stacklevel=2)
    t0 = time.perf_counter()
    uids = [engine.submit(r.prompt, max_new_tokens=r.max_new_tokens)
            for r in batch.requests]
    first_tok_s = None
    steps = 0
    while engine.pending or engine.num_active:
        for ev in engine.step():
            if ev.kind == "token" and ev.index == 0 and first_tok_s is None:
                first_tok_s = time.perf_counter() - t0
        steps += 1
        if max_steps is not None and steps >= max_steps:
            break
    total_s = time.perf_counter() - t0
    prefill_s = first_tok_s if first_tok_s is not None else total_s

    S = batch.num_slots
    budget = max(int(batch.max_new().max(initial=0)), 1)
    gen = np.zeros((S, budget), np.int64)
    logprob = np.zeros((S,), np.float32)
    tokens = np.zeros((S,), np.int64)
    stopped = np.zeros((S,), bool)
    for i, uid in enumerate(uids):
        res = engine.result(uid)
        gen[i, : len(res.tokens)] = res.tokens
        logprob[i] = res.logprob_sum
        tokens[i] = len(res.tokens)
        stopped[i] = res.stopped
    metrics = {"logprob_sum": logprob, "tokens": tokens, "stopped": stopped}
    return BatchResult(batch=batch, tokens=gen, metrics=metrics,
                       decode_steps=steps, prefill_s=prefill_s,
                       decode_s=max(total_s - prefill_s, 1e-9))


# ---------------------------------------------------------------------------
# CLI: Poisson arrival trace through the engine
# ---------------------------------------------------------------------------

def poisson_trace(rng: np.random.Generator, n: int, rate_hz: float,
                  min_prompt: int, max_prompt: int, vocab: int,
                  max_new: int, users: int = 1,
                  shared_frac: float = 0.0, shared_len: int = 0):
    """[(arrival_offset_s, prompt, max_new, user)] — synthetic open-loop
    traffic; requests attribute uniformly to ``users`` synthetic users.

    ``shared_frac`` of the requests open with a fixed ``shared_len``-token
    prefix (one "system prompt" drawn per trace) — the workload shape
    prefix KV caching exploits."""
    shared = rng.integers(1, vocab, shared_len).tolist() if shared_len else []
    t = 0.0
    out = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate_hz)) if rate_hz > 0 else 0.0
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        if shared and plen > shared_len and rng.random() < shared_frac:
            prompt = shared + rng.integers(1, vocab,
                                           plen - shared_len).tolist()
        else:
            prompt = rng.integers(1, vocab, plen).tolist()
        out.append((t, prompt, max_new, int(rng.integers(0, users))))
    return out


def serve_trace(engine: ContinuousEngine, trace, *,
                clock=time.perf_counter, quiet: bool = True):
    """Replay an arrival trace through the engine in (scaled) real time.

    Submits each request once its arrival offset elapses, stepping the
    engine whenever it has work.  Returns ``(results, wall_s)`` with
    results in submission order.
    """
    t0 = clock()
    uids = []
    i = 0
    while i < len(trace) or engine.pending or engine.num_active:
        now = clock() - t0
        while i < len(trace) and trace[i][0] <= now:
            _, prompt, max_new, *rest = trace[i]
            uids.append(engine.submit(prompt, max_new_tokens=max_new,
                                      user=rest[0] if rest else 0))
            i += 1
        if engine.pending or engine.num_active:
            for ev in engine.step():
                if not quiet and ev.kind == "done":
                    r = ev.result
                    print(f"  uid={r.uid} slot={r.slot} prompt={r.prompt_len} "
                          f"-> bucket={r.bucket} gen={len(r.tokens)} "
                          f"logprob_sum={r.logprob_sum:.2f} "
                          f"ttft={r.ttft_s * 1e3:.1f}ms")
        elif i < len(trace):
            time.sleep(min(max(trace[i][0] - now, 0.0), 0.01))
    wall = clock() - t0
    return [engine.result(u) for u in uids], wall


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--buckets", default="8,16",
                    help="comma-separated prefill bucket ladder")
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=12)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate (requests/s); 0 = all at once")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--users", type=int, default=4,
                    help="synthetic user population for per-user windows")
    ap.add_argument("--prefill-batch", type=int, default=4,
                    help="max same-bucket admissions per prefill program")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the radix prefix KV cache")
    ap.add_argument("--prefix-block", type=int, default=4,
                    help="tokens per prefix-cache trie node")
    ap.add_argument("--shared-frac", type=float, default=0.0,
                    help="fraction of requests opening with a shared prefix")
    ap.add_argument("--shared-len", type=int, default=0,
                    help="token length of the shared prefix")
    args = ap.parse_args(argv)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    if args.max_prompt > buckets[-1]:
        raise SystemExit(f"--max-prompt {args.max_prompt} exceeds the "
                         f"largest bucket {buckets[-1]}")
    config = ServeConfig(arch=args.arch, num_slots=args.slots,
                         prefill_buckets=buckets, max_new_tokens=args.gen,
                         temperature=args.temperature, seed=args.seed,
                         model_parallel=args.model_parallel, full=args.full,
                         prefill_batch=args.prefill_batch,
                         prefix_cache=not args.no_prefix_cache,
                         prefix_block=args.prefix_block)
    engine = build_engine(config)
    metrics = WindowedMetrics(window=32, half_life_s=60.0)
    engine.subscribe(metrics.observe)

    plan = decode_metrics_plan(config.num_slots, config.num_slots)
    print(f"arch={args.arch} slots={config.num_slots} buckets={buckets} "
          f"gen<={args.gen} requests={args.requests} rate={args.rate}/s")
    print(f"per-step aggregation plan: {plan.describe()}")

    rng = np.random.default_rng(args.seed)
    vocab = engine.backend.vocab_size
    trace = poisson_trace(rng, args.requests, args.rate, args.min_prompt,
                          args.max_prompt, vocab, args.gen,
                          users=max(1, args.users),
                          shared_frac=args.shared_frac,
                          shared_len=args.shared_len)
    results, wall = serve_trace(engine, trace, quiet=False)

    ttfts = np.array([r.ttft_s for r in results])
    new_tokens = sum(len(r.tokens) for r in results)
    st = engine.stats
    print(f"served {len(results)} requests, {new_tokens} tokens in "
          f"{wall:.2f}s ({new_tokens / wall:.0f} tok/s) | "
          f"steps={st.steps} slot_reuses={st.slot_reuses} "
          f"prefills={st.prefill_calls} batched={st.batched_admissions} "
          f"ttft p50={np.percentile(ttfts, 50) * 1e3:.1f}ms "
          f"p99={np.percentile(ttfts, 99) * 1e3:.1f}ms")
    print(f"compiled shapes: {engine.compile_counts()} "
          f"(bound: {engine.compile_bound()})")
    if engine.prefix is not None:
        ps = engine.prefix.stats
        fp = metrics.fleet_prefix()
        print(f"prefix cache: nodes={engine.prefix.node_count} "
              f"resident={engine.prefix.total_bytes}B "
              f"(fold-accounted {engine.prefix.accounted_bytes()}B) "
              f"hit_rate={ps.hit_rate():.0%} "
              f"bytes_saved={ps.bytes_saved} evictions={ps.evictions} "
              f"folds={ps.folds}")
        print(f"fleet prefix windows: hit_rate={fp['hit_rate']:.0%} "
              f"hit_tokens={fp['hit_tokens']:.0f}/"
              f"{fp['prompt_tokens']:.0f} "
              f"bytes_saved={fp['bytes_saved']:.0f}")
    now = time.perf_counter()
    print(f"per-user windows (last {metrics.window} requests, token-rate "
          f"half-life {metrics.half_life_s:g}s; fleet tokens "
          f"{metrics.fleet_tokens():.0f}):")
    for user, row in metrics.summary(now).items():
        print(f"  user={user} requests={row['requests']} "
              f"latency={row['latency_s'] * 1e3:.1f}ms "
              f"ttft={row['ttft_s'] * 1e3:.1f}ms "
              f"tokens/req={row['tokens']:.1f} "
              f"token_rate={row['token_rate']:.1f}")
    return 0


if __name__ == "__main__":
    main()
