"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every while body ONCE, so a
scan-over-layers program under-reports FLOPs/bytes by ~num_layers.  This
module re-derives the three roofline terms directly from ``compiled.as_text()``:

1. parse the module into computations, with a per-computation symbol table
   (HLO text references operands by %name without inline types),
2. build the call graph (while bodies/conditions, fusions, calls) and
   extract each while loop's static trip count from the constant bound in
   its condition computation,
3. cost each computation —
     * flops: dot ops (2 * result_elems * contracted_elems) + convolutions,
     * memory bytes: a single-pass fusion model (see below),
     * collective bytes: operand bytes of all-gather / all-reduce /
       reduce-scatter / all-to-all / collective-permute,
4. propagate through the call graph with trip-count multipliers.

Memory model (the "fused single-pass" model):
  * a fusion op reads each operand once and writes its result once, EXCEPT
      - an operand consumed only via dynamic-slice contributes the SLICE
        bytes (gather/DS reads rows, not the table),
      - the accumulator pattern (operand aliased to a dynamic-update-slice
        root, possibly through converts) contributes the UPDATE bytes on
        both the read and the write side (in-place on TPU);
  * top-level non-fused ops: operands + result, with the same dus/slice
    rules; `convert`/`bitcast`/`tuple`/... are free (always fused on TPU);
  * fusion-internal intermediates are free (they live in registers/VMEM).

All sizes are PER-PARTITION (the text is the post-partitioning module), so
terms divide by per-chip peak rates directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"bitcast", "tuple", "get-tuple-element", "parameter", "constant",
             "after-all", "iota", "while", "conditional", "custom-call",
             "partition-id", "replica-id", "convert", "copy-start", "copy-done",
             "reshape"}
_SLICE_OPS = {"dynamic-slice", "gather", "slice"}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{\s*$")


def _shape_list_bytes(shapes: List[Tuple[str, str]]) -> int:
    return sum(_shape_bytes(d, s) for d, s in shapes)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class OpLine:
    name: str
    op: str
    rhs: str
    res_shapes: List[Tuple[str, str]]
    opnds: List[str]


@dataclasses.dataclass
class Comp:
    name: str
    params: List[str] = dataclasses.field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, str]]] = dataclasses.field(default_factory=dict)
    lines: List[OpLine] = dataclasses.field(default_factory=list)
    constants: List[int] = dataclasses.field(default_factory=list)
    root: Optional[str] = None
    calls: List[str] = dataclasses.field(default_factory=list)
    while_children: List[Tuple[str, str]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloCost:
    flops: float
    mem_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    loops: List[Tuple[str, int]]
    raw_cost_analysis: Dict[str, float]
    score_bytes: float = 0.0   # traffic of (…, k*S, S)-shaped f32 score tensors

    def flash_substituted_mem(self) -> float:
        """Memory bytes if attention scores stay in VMEM (the validated
        Pallas flash kernel, kernels/flash_attention.py): all S^2-shaped
        score traffic is removed; Q/K/V/O traffic is already counted by the
        surrounding ops. Reported alongside the raw term — the kernel cannot
        appear in a CPU-compiled dry-run."""
        return self.mem_bytes - self.score_bytes


def _strip_meta(line: str) -> str:
    for key in (", metadata={", ", backend_config=", ", sharding={"):
        i = line.find(key)
        if i >= 0:
            line = line[:i]
    return line


def _operand_names(rhs: str) -> List[str]:
    paren = rhs.find("(")
    if paren < 0:
        return []
    depth = 0
    end = paren
    for i, ch in enumerate(rhs[paren:], start=paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = rhs[paren + 1:end]
    return re.findall(r"%([\w\.\-]+)", inner)


def parse_module(text: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry: Optional[str] = None
    cur: Optional[Comp] = None
    for raw in text.splitlines():
        s = raw.strip()
        h = _HEADER_RE.match(s)
        if h and ("=" not in s.split("(")[0]):
            cur = Comp(name=h.group(2))
            comps[cur.name] = cur
            if h.group(1):
                entry = cur.name
            for pm in re.finditer(r"([\w\.\-]+)\s*:\s*([^,()]*(?:\([^)]*\))?[^,]*)",
                                  h.group(3)):
                cur.params.append(pm.group(1))
                cur.symbols[pm.group(1)] = _SHAPE_RE.findall(pm.group(2))
            continue
        if cur is None or not s or s == "}":
            if s == "}":
                cur = None
            continue
        d = _DEF_RE.match(_strip_meta(s))
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        for m in re.finditer(r"constant\((-?\d+)\)", rhs):
            cur.constants.append(int(m.group(1)))
        opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        op = opm.group(1) if opm else ""
        paren = rhs.find("(")
        res_shapes = _SHAPE_RE.findall(rhs[:paren] if paren >= 0 else rhs)
        cur.symbols[name] = res_shapes
        line = OpLine(name=name, op=op, rhs=rhs, res_shapes=res_shapes,
                      opnds=_operand_names(rhs))
        cur.lines.append(line)
        if s.lstrip().startswith("ROOT") or d.group(0).lstrip().startswith("ROOT"):
            cur.root = name
        if raw.lstrip().startswith("ROOT"):
            cur.root = name
        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", rhs)
            cond = re.search(r"condition=%?([\w\.\-]+)", rhs)
            if body and cond:
                cur.while_children.append((body.group(1), cond.group(1)))
        else:
            for key in ("calls=", "to_apply="):
                mm = re.search(key + r"%?([\w\.\-]+)", rhs)
                if mm:
                    cur.calls.append(mm.group(1))
            if op == "conditional":
                for mm in re.finditer(
                        r"(?:true_computation=|false_computation=|"
                        r"branch_computations=\{)([^,}]+(?:,[^,}]+)*)", rhs):
                    for nm in mm.group(1).split(","):
                        cur.calls.append(nm.strip().lstrip("%"))
    return comps, entry


# ---------------------------------------------------------------------------
# fusion-body single-pass memory model
# ---------------------------------------------------------------------------

def _fusion_param_classes(comp: Comp) -> Tuple[Dict[str, str], int]:
    """Classify each fusion param: 'slice' (only dynamic-sliced/gathered),
    'alias' (accumulator: reaches a dus at operand 0, root-aliased),
    'full'. Returns (classes, root_write_bytes)."""
    # map: value name -> originating param (through converts/bitcasts)
    origin: Dict[str, str] = {p: p for p in comp.params}
    uses: Dict[str, List[Tuple[str, int]]] = {}
    for ln in comp.lines:
        if ln.op in ("convert", "bitcast", "copy", "reshape") and ln.opnds:
            src = origin.get(ln.opnds[0])
            if src is not None:
                origin[ln.name] = src
        for i, o in enumerate(ln.opnds):
            uses.setdefault(o, []).append((ln.op, i))
            if o in origin and origin[o] != o:
                uses.setdefault(origin[o], []).append((ln.op, i))

    classes: Dict[str, str] = {}
    dus_update_bytes = 0
    root_dus = False
    # find dus lines; check root aliasing chain
    root_origin = None
    if comp.root is not None:
        # walk back from root through converts to a dus
        back = comp.root
        seen = set()
        while back not in seen:
            seen.add(back)
            ln = next((l for l in comp.lines if l.name == back), None)
            if ln is None:
                break
            if ln.op == "dynamic-update-slice":
                root_dus = True
                if len(ln.opnds) > 1:
                    upd = comp.symbols.get(ln.opnds[1], [])
                    dus_update_bytes = _shape_list_bytes(upd)
                root_origin = origin.get(ln.opnds[0])
                break
            if ln.op in ("convert", "bitcast", "copy", "reshape") and ln.opnds:
                back = ln.opnds[0]
                continue
            break

    for p in comp.params:
        u = uses.get(p, [])
        if root_dus and root_origin == p:
            classes[p] = "alias"
        elif u and all(op in _SLICE_OPS and idx == 0 for op, idx in u):
            classes[p] = "slice"
        else:
            classes[p] = "full"

    if comp.root is not None and root_dus:
        root_bytes = 2 * dus_update_bytes     # write update + read-modify
    else:
        root_bytes = _shape_list_bytes(comp.symbols.get(comp.root, [])) \
            if comp.root else 0
    return classes, root_bytes


def _slice_read_bytes(comp: Comp, param: str) -> int:
    """Bytes actually read from a 'slice'-class param (sum of slice results)."""
    total = 0
    for ln in comp.lines:
        if ln.op in _SLICE_OPS and ln.opnds and ln.opnds[0] == param:
            total += _shape_list_bytes(ln.res_shapes)
    return total


def _fusion_mem(comps: Dict[str, Comp], body_name: str,
                call_opnd_shapes: List[List[Tuple[str, str]]],
                memo: Dict[str, Tuple[Dict[str, str], int]]) -> int:
    body = comps.get(body_name)
    if body is None:
        return 0
    if body_name not in memo:
        memo[body_name] = _fusion_param_classes(body)
    classes, root_bytes = memo[body_name]
    total = root_bytes
    for i, p in enumerate(body.params):
        cls = classes.get(p, "full")
        if cls == "alias":
            continue                      # in-place accumulator: counted at root
        if cls == "slice":
            total += _slice_read_bytes(body, p)
        else:
            shapes = body.symbols.get(p, [])
            total += _shape_list_bytes(shapes)
    return total


# ---------------------------------------------------------------------------
# per-computation costing + aggregation
# ---------------------------------------------------------------------------

def _trip_count(comps: Dict[str, Comp], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None or not cond.constants:
        return 1
    pos = [c for c in cond.constants if c > 0]
    return max(pos) if pos else 1


def _dot_flops(comp: Comp, ln: OpLine) -> float:
    contract = 1
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln.rhs)
    lhs_shape = comp.symbols.get(ln.opnds[0], []) if ln.opnds else []
    if lc and lhs_shape:
        lhs_dims = lhs_shape[0][1].split(",") if lhs_shape[0][1] else []
        for idx in lc.group(1).split(","):
            if idx and lhs_dims:
                contract *= int(lhs_dims[int(idx)])
    res_elems = sum(_shape_elems(s) for _, s in ln.res_shapes)
    return 2.0 * res_elems * contract


def _score_bytes(shapes: List[Tuple[str, str]], seq: Optional[int]) -> int:
    """bytes of shapes that look like attention scores: trailing dim == seq
    and second-to-last a positive multiple of seq (covers (B,H,S,S) and the
    (B,H*S,S) reshapes)."""
    if not seq:
        return 0
    total = 0
    for d, dims in shapes:
        parts = [int(x) for x in dims.split(",") if x]
        if len(parts) >= 2 and parts[-1] == seq and parts[-2] % seq == 0 \
                and parts[-2] > 0:
            total += _shape_bytes(d, dims)
    return total


def _cost_comp(comps: Dict[str, Comp], comp: Comp,
               fusion_memo: Dict[str, Tuple[Dict[str, str], int]],
               seq: Optional[int] = None):
    """(flops, mem, coll, coll_by_kind) for one computation body, treating
    fusion calls with the single-pass model and skipping free ops."""
    fl = mem = coll = 0.0
    score = 0.0
    ckind: Dict[str, float] = {}
    for ln in comp.lines:
        opnd_shapes: List[List[Tuple[str, str]]] = [
            comp.symbols.get(o, []) for o in ln.opnds]
        flat_opnds = [s for sub in opnd_shapes for s in sub]
        if ln.op == "dot":
            fl += _dot_flops(comp, ln)
            mem += _shape_list_bytes(ln.res_shapes) + _shape_list_bytes(flat_opnds)
            score += _score_bytes(ln.res_shapes, seq) + _score_bytes(flat_opnds, seq)
        elif ln.op == "convolution":
            res_elems = sum(_shape_elems(s) for _, s in ln.res_shapes)
            if flat_opnds:
                fl += 2.0 * res_elems * _shape_elems(flat_opnds[-1][1])
            mem += _shape_list_bytes(ln.res_shapes) + _shape_list_bytes(flat_opnds)
        elif ln.op == "fusion":
            body = re.search(r"calls=%?([\w\.\-]+)", ln.rhs)
            if body:
                mem += _fusion_mem(comps, body.group(1), opnd_shapes, fusion_memo)
                score += _score_bytes(ln.res_shapes, seq) + \
                    _score_bytes(flat_opnds, seq)
        elif any(c in ln.op for c in _COLLECTIVES):
            kind = next(c for c in _COLLECTIVES if c in ln.op)
            nbytes = _shape_list_bytes(flat_opnds)
            coll += nbytes
            ckind[kind] = ckind.get(kind, 0.0) + nbytes
            mem += nbytes + _shape_list_bytes(ln.res_shapes)
        elif ln.op == "dynamic-update-slice":
            upd = comp.symbols.get(ln.opnds[1], []) if len(ln.opnds) > 1 else []
            mem += 2 * _shape_list_bytes(upd)
        elif ln.op in _SLICE_OPS:
            mem += 2 * _shape_list_bytes(ln.res_shapes)
        elif ln.op == "scatter":
            upd = comp.symbols.get(ln.opnds[-1], []) if ln.opnds else []
            mem += 3 * _shape_list_bytes(upd)
        elif ln.op in _FREE_OPS or not ln.op:
            pass
        else:
            mem += _shape_list_bytes(ln.res_shapes) + _shape_list_bytes(flat_opnds)
            score += _score_bytes(ln.res_shapes, seq) + _score_bytes(flat_opnds, seq)
    return fl, mem, coll, ckind, score


def _fusion_flops(comps: Dict[str, Comp], name: str, memo: Dict[str, float]) -> float:
    """dots can appear inside fusion/call bodies — count them (flops only)."""
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    if comp is None:
        return 0.0
    memo[name] = 0.0
    fl = 0.0
    for ln in comp.lines:
        if ln.op == "dot":
            fl += _dot_flops(comp, ln)
        elif ln.op == "fusion":
            body = re.search(r"calls=%?([\w\.\-]+)", ln.rhs)
            if body:
                fl += _fusion_flops(comps, body.group(1), memo)
    memo[name] = fl
    return fl


def analyze_hlo(text: str, raw_cost: Optional[Dict[str, float]] = None,
                seq_len: Optional[int] = None) -> HloCost:
    comps, entry = parse_module(text)
    if entry is None:
        called = {c for comp in comps.values() for c in comp.calls}
        called |= {b for comp in comps.values() for b, _ in comp.while_children}
        called |= {c for comp in comps.values() for _, c in comp.while_children}
        entry = next((nm for nm in comps if nm not in called), None)

    fusion_memo: Dict[str, Tuple[Dict[str, str], int]] = {}
    fusion_fl_memo: Dict[str, float] = {}
    loops: List[Tuple[str, int]] = []
    agg_memo: Dict[str, tuple] = {}

    def aggregate(name: str):
        if name in agg_memo:
            return agg_memo[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0, {}, 0.0)
        agg_memo[name] = (0.0, 0.0, 0.0, {}, 0.0)
        fl, mem, coll, ckind, score = _cost_comp(comps, comp, fusion_memo,
                                                 seq_len)
        ckind = dict(ckind)
        for ln in comp.lines:
            if ln.op == "fusion":
                body = re.search(r"calls=%?([\w\.\-]+)", ln.rhs)
                if body:
                    fl += _fusion_flops(comps, body.group(1), fusion_fl_memo)
        for child in comp.calls:
            cf, cm, cc, ck, _cs = aggregate(child)
            # non-fusion calls (reduce bodies etc.): flops + collectives only
            child_comp = comps.get(child)
            if child_comp is not None and child not in {
                    re.search(r"calls=%?([\w\.\-]+)", l.rhs).group(1)
                    for l in comp.lines if l.op == "fusion"
                    and re.search(r"calls=%?([\w\.\-]+)", l.rhs)}:
                fl += cf
                coll += cc
                for k, v in ck.items():
                    ckind[k] = ckind.get(k, 0) + v
        for body, cond in comp.while_children:
            n = _trip_count(comps, cond)
            loops.append((body, n))
            bf, bm, bc, bk, bs = aggregate(body)
            fl += n * bf
            mem += n * bm
            coll += n * bc
            score += n * bs
            for k, v in bk.items():
                ckind[k] = ckind.get(k, 0) + n * v
        agg_memo[name] = (fl, mem, coll, ckind, score)
        return agg_memo[name]

    fl, mem, coll, ckind, score = aggregate(entry) if entry \
        else (0.0, 0.0, 0.0, {}, 0.0)
    return HloCost(flops=fl, mem_bytes=mem, coll_bytes=coll, coll_by_kind=ckind,
                   loops=loops, raw_cost_analysis=dict(raw_cost or {}),
                   score_bytes=score)


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e per-chip constants; see EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-chip effective)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    mem_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    model_flops: float = 0.0   # analytic, per chip

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def roofline_fraction(self) -> float:
        """useful-compute time / bounding term: the score we hillclimb.
        = (model_flops/peak) / max(compute_s, memory_s, collective_s)."""
        if not self.bound_s:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.bound_s


def roofline_terms(cost: HloCost, *, model_flops_per_chip: float = 0.0) -> Roofline:
    """cost is per-partition (post-SPMD module) -> per-chip seconds."""
    return Roofline(
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.mem_bytes / HBM_BW,
        collective_s=cost.coll_bytes / ICI_BW,
        flops=cost.flops, mem_bytes=cost.mem_bytes, coll_bytes=cost.coll_bytes,
        coll_by_kind=cost.coll_by_kind,
        model_flops=model_flops_per_chip,
    )
