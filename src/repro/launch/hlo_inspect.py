"""Profiling aid for the hillclimb loop: rank ops by their trip-count-scaled
contribution to the memory term, under the same fused single-pass model as
hlo_analysis.

  PYTHONPATH=src python -m repro.launch.hlo_inspect /tmp/foo.hlo [--top 20]

(Generate the .hlo with `python -m repro.launch.dryrun ... --keep-hlo`.)
"""
from __future__ import annotations

import argparse
import re
from typing import Dict, List, Tuple

from .hlo_analysis import (_COLLECTIVES, _FREE_OPS, _SLICE_OPS,
                           _fusion_mem, _shape_list_bytes, _trip_count,
                           analyze_hlo, parse_module)


def _multipliers(comps, entry) -> Dict[str, int]:
    mult: Dict[str, int] = {}

    def walk(name: str, m: int):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        comp = comps.get(name)
        if comp is None:
            return
        for child in comp.calls:
            walk(child, m)
        for body, cond in comp.while_children:
            walk(body, m * _trip_count(comps, cond))
    if entry:
        walk(entry, 1)
    return mult


def top_ops(text: str, top: int = 25) -> List[Tuple[float, str, str]]:
    comps, entry = parse_module(text)
    mult = _multipliers(comps, entry)
    fusion_memo: Dict[str, tuple] = {}
    rows: List[Tuple[float, str, str]] = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue
        for ln in comp.lines:
            flat_opnds = [s for o in ln.opnds for s in comp.symbols.get(o, [])]
            if ln.op == "fusion":
                body = re.search(r"calls=%?([\w\.\-]+)", ln.rhs)
                nbytes = _fusion_mem(comps, body.group(1), [], fusion_memo) \
                    if body else 0
            elif any(c in ln.op for c in _COLLECTIVES):
                nbytes = _shape_list_bytes(flat_opnds) + \
                    _shape_list_bytes(ln.res_shapes)
            elif ln.op == "dynamic-update-slice":
                upd = comp.symbols.get(ln.opnds[1], []) if len(ln.opnds) > 1 else []
                nbytes = 2 * _shape_list_bytes(upd)
            elif ln.op in _SLICE_OPS:
                nbytes = 2 * _shape_list_bytes(ln.res_shapes)
            elif ln.op in _FREE_OPS or not ln.op:
                continue
            else:
                nbytes = _shape_list_bytes(ln.res_shapes) + \
                    _shape_list_bytes(flat_opnds)
            scaled = nbytes * m
            if scaled > 0:
                rows.append((scaled, ln.op,
                             f"x{m} {cname[:26]:26s} {ln.rhs[:110]}"))
    rows.sort(key=lambda r: -r[0])
    return rows[:top]


def summarize(path: str, top: int = 25) -> None:
    text = open(path).read()
    cost = analyze_hlo(text)
    print(f"flops={cost.flops:.3e}  mem={cost.mem_bytes:.3e}B  "
          f"coll={cost.coll_bytes:.3e}B")
    print("loops:", cost.loops[:12])
    print("collectives by kind:", {k: f"{v:.2e}" for k, v in cost.coll_by_kind.items()})
    print(f"\ntop {top} ops by trip-scaled memory bytes:")
    for nbytes, op, line in top_ops(text, top):
        print(f"{nbytes/1e9:10.2f} GB  {op:20s} {line[:150]}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_path")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    summarize(args.hlo_path, args.top)
