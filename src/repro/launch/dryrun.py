import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, prove it fits (memory_analysis) and extract the roofline
terms (cost_analysis + trip-count-corrected HLO analysis).

The two lines above MUST stay first: jax locks the device count at first
backend init, and the 512 placeholder host devices exist only for this
entry point (smoke tests and benches see 1 device).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod] --out results.json
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config, valid_cells   # noqa: E402
from ..models import RunCtx                                       # noqa: E402
from .mesh import make_production_mesh                            # noqa: E402
from .steps import build_step                                     # noqa: E402
from . import hlo_analysis as ha                                  # noqa: E402


def _mem_analysis_dict(compiled) -> dict:
    out = {}
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_hbm_bytes"] = int(
        out.get("argument_size_in_bytes", 0) + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0) - out.get("alias_size_in_bytes", 0))
    return out


def model_flops_for_cell(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the whole step (all chips)."""
    n_active = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             moe_impl: str = "replicated", ce_chunk: int = 0,
             attn_chunk: int = 0, microbatches: int = 1,
             remat: str = "full", keep_hlo: bool = False,
             f32_chains: bool = False, seq_parallel: bool = False) -> dict:
    from ..models import common as model_common
    from ..dist import sharding as shd
    model_common.set_f32_chains(f32_chains)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name not in valid_cells(cfg):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    decode_impl = "flash" if (shape.kind == "decode" and shape.seq_len > 100_000) \
        else "dense"
    ctx = RunCtx(mesh=mesh, moe_impl=moe_impl,
                 attn_chunk=attn_chunk or None, ce_chunk=ce_chunk,
                 remat=remat, decode_impl=decode_impl)
    kw = {"ctx": ctx}
    if shape.kind == "train" and microbatches > 1:
        kw["num_microbatches"] = microbatches
    if seq_parallel:
        base = shd.TRAIN_RULES if shape.kind == "train" else shd.SERVE_RULES
        kw["rules"] = dict(base, seq="model")

    t0 = time.time()
    built = build_step(cfg, mesh, shape, **kw)
    lowered = built.fn.lower(*built.abstract_args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_analysis_dict(compiled)
    try:
        raw_cost = dict(compiled.cost_analysis())
    except Exception as e:
        raw_cost = {"error": str(e)}
    print("memory_analysis:", json.dumps(mem), flush=True)
    print("cost_analysis[flops]:", raw_cost.get("flops"), flush=True)

    text = compiled.as_text()
    cost = ha.analyze_hlo(text, raw_cost={k: v for k, v in raw_cost.items()
                                          if isinstance(v, (int, float))},
                          seq_len=shape.seq_len if shape.kind != "decode" else None)
    chips = mesh.devices.size
    mf = model_flops_for_cell(cfg, shape)
    rf = ha.roofline_terms(cost, model_flops_per_chip=mf / chips)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(chips),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "per_chip": {
            "flops": cost.flops, "mem_bytes": cost.mem_bytes,
            "coll_bytes": cost.coll_bytes,
            "coll_by_kind": cost.coll_by_kind,
        },
        "roofline": {
            "compute_s": rf.compute_s, "memory_s": rf.memory_s,
            "collective_s": rf.collective_s, "dominant": rf.dominant,
            "bound_s": rf.bound_s,
            "model_flops_total": mf,
            "useful_flops_ratio": rf.useful_flops_ratio(),
            "roofline_fraction": rf.roofline_fraction(),
            "score_bytes": cost.score_bytes,
            "flash_sub_memory_s": cost.flash_substituted_mem() / ha.HBM_BW,
        },
        "loops": cost.loops[:20],
        "raw_cost_analysis_flops": raw_cost.get("flops"),
        "options": {"moe_impl": moe_impl, "ce_chunk": ce_chunk,
                    "attn_chunk": attn_chunk, "microbatches": microbatches,
                    "remat": remat, "decode_impl": decode_impl,
                    "multi_pod": multi_pod, "f32_chains": f32_chains,
                    "seq_parallel": seq_parallel},
    }
    if keep_hlo:
        result["hlo_path"] = f"/tmp/{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}.hlo"
        with open(result["hlo_path"], "w") as f:
            f.write(text)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-impl", default="replicated",
                    choices=["replicated", "a2a"])
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--f32-chains", action="store_true",
                    help="baseline precision policy (f32 norm/rotary/proj chains)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="shard the residual stream's seq dim over 'model'")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    results = []
    ok = True
    for arch in archs:
        cfg = get_config(arch)
        shapes = valid_cells(cfg) if args.shape == "all" else [args.shape]
        for shape_name in shapes:
            tag = f"{arch} x {shape_name} ({'multi' if args.multi_pod else 'single'}-pod)"
            print(f"=== dry-run {tag}", flush=True)
            try:
                r = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                             moe_impl=args.moe_impl, ce_chunk=args.ce_chunk,
                             attn_chunk=args.attn_chunk,
                             microbatches=args.microbatches, remat=args.remat,
                             keep_hlo=args.keep_hlo,
                             f32_chains=args.f32_chains,
                             seq_parallel=args.seq_parallel)
                results.append(r)
                if not r.get("skipped"):
                    rf = r["roofline"]
                    print(f"    compile={r['compile_s']}s dominant={rf['dominant']} "
                          f"compute={rf['compute_s']:.4f}s memory={rf['memory_s']:.4f}s "
                          f"collective={rf['collective_s']:.4f}s "
                          f"useful={rf['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:
                ok = False
                results.append({"arch": arch, "shape": shape_name,
                                "error": repr(e)})
                print(f"    FAILED: {e!r}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
