"""Production meshes. A FUNCTION, not a module constant — importing this
module never touches jax device state (the dry-run sets
``--xla_force_host_platform_device_count`` before first jax init)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = one 256-chip v5e pod; (2,16,16) = two pods over DCN.

    Axes: 'data' = data parallel (fast ICI), 'model' = tensor/expert/sequence
    parallel (fast ICI), 'pod' = the DCN-connected slow axis (data-parallel
    across pods; gradients cross it once per step via the hierarchical
    monoid reduction).
    """
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: Optional[int] = None) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / single host): (data, model)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))
