"""repro.launch — meshes, jit step builders, dry-run + training entry points.

NOTE: ``dryrun`` is intentionally NOT imported here — it sets
``--xla_force_host_platform_device_count=512`` at import time and must only
be imported as the main entry point (``python -m repro.launch.dryrun``).
"""
from .mesh import make_host_mesh, make_mesh, make_production_mesh
from .steps import (BuiltStep, build_step, cache_shardings, make_decode_step,
                    make_prefill_step, make_train_step, trim_rules)
