"""End-to-end training driver: data -> train_step -> metrics -> checkpoint,
with preemption handling and monoid-merged restart.

This is the runnable (CPU-scale) counterpart of the dry-run: the same
make_train_step powers both; here it executes on the host mesh with a smoke
or custom config.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ShapeCell, context_spec, get_config
from ..core import monoids
from ..checkpoint import CheckpointStore
from ..data import DataConfig, SyntheticCorpus
from ..data import init_stats, make_stream_stats, update_stats
from ..models import RunCtx, init_params
from ..optim import OptConfig, init_opt_state
from ..runtime import PreemptionHandler
from .mesh import make_host_mesh
from .steps import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "qwen3-0.6b"
    smoke: bool = True
    steps: int = 20
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    microbatches: int = 1
    lossy: Optional[str] = None  # grad-compression annotation ("topk:0.01",
                                 #   "blocktopk:0.001", "int8"); EF residual
                                 #   rides opt_state["ef"]
    ragged: bool = False   # corpus emits valid_mask; stats fold only real tokens
    moe_impl: str = "replicated"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    model_parallel: int = 1
    log_every: int = 5
    opt: OptConfig = dataclasses.field(default_factory=lambda: OptConfig(
        peak_lr=1e-3, warmup_steps=10, decay_steps=1000))


def train(tc: TrainerConfig, *, preemption: Optional[PreemptionHandler] = None
          ) -> Dict[str, Any]:
    cfg = get_config(tc.arch, smoke=tc.smoke)
    mesh = make_host_mesh(model=tc.model_parallel)
    shape = ShapeCell("custom", "train", tc.seq_len, tc.global_batch)
    ctx = RunCtx(mesh=mesh, moe_impl=tc.moe_impl)
    built = make_train_step(cfg, mesh, shape, opt=tc.opt, ctx=ctx,
                            num_microbatches=tc.microbatches, lossy=tc.lossy,
                            donate=True)

    # init (or restore) state, sharded per the step's in_shardings
    key = jax.random.PRNGKey(tc.seed)
    params, _ = init_params(cfg, key)
    params = jax.device_put(params, built.in_shardings[0])
    opt_state = jax.device_put(init_opt_state(params, with_ef=tc.lossy is not None),
                               built.in_shardings[1])

    # data: host-sharded synthetic corpus (+ stub modality context)
    ctx_spec = context_spec(cfg, tc.global_batch)
    corpus = SyntheticCorpus(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                   global_batch=tc.global_batch, seed=tc.seed,
                   ragged=tc.ragged),
        context_shape=None if ctx_spec is None else ctx_spec.shape[1:])

    # metrics stream: Sum-monoid accumulator across steps (in-mapper
    # combining), checkpointed and monoid-merged on restart.
    msum = monoids.sum_
    metrics_acc = None
    stats_monoid = make_stream_stats()
    stream_stats = init_stats(stats_monoid)

    store = CheckpointStore(tc.ckpt_dir) if tc.ckpt_dir else None
    start_step = 0
    if store is not None and store.latest_step() is not None:
        start_step, (params, opt_state) = store.restore(
            (params, opt_state),
            shardings=(built.in_shardings[0], built.in_shardings[1]))
        restored = store.restore_aggregate("metrics", like=_metrics_like(built))
        if restored is not None:
            metrics_acc = restored
        restored_ss = store.restore_aggregate("stream_stats", like=stream_stats)
        if restored_ss is not None:
            stream_stats = restored_ss
        print(f"restored checkpoint at step {start_step}")

    history = []
    t_last = time.time()
    for step in range(start_step, tc.steps):
        batch = corpus(step)
        # ragged corpora carry a valid_mask: the jitted step's in_shardings
        # cover the model inputs only, and the stream stats fold it through
        # the planner's mask path (padding tokens count nothing)
        mask = batch.pop("valid_mask", None)
        params, opt_state, metrics = built.fn(params, opt_state, batch)
        stream_stats = update_stats(stream_stats, batch["tokens"],
                                    valid_mask=mask)
        metrics_acc = metrics if metrics_acc is None else \
            msum.combine(metrics_acc, metrics)
        if (step + 1) % tc.log_every == 0 or step + 1 == tc.steps:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t_last
            t_last = time.time()
            print(f"step {step+1:5d} loss={m['loss']:.4f} "
                  f"grad_norm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                  f"({dt:.2f}s)", flush=True)
            history.append({"step": step + 1, **m})
        stop = preemption is not None and preemption.should_stop
        if store is not None and ((step + 1) % tc.ckpt_every == 0 or stop
                                  or step + 1 == tc.steps):
            store.save_async(step + 1, (params, opt_state), aggregates={
                "metrics": ("sum", metrics_acc),
                "stream_stats": (stats_monoid.name, stream_stats),
            })
        if stop:
            print(f"preempted at step {step+1}: checkpoint saved, exiting")
            break
    if store is not None:
        store.wait()
    return {"history": history, "metrics_acc": metrics_acc,
            "stream_stats": stream_stats, "params": params,
            "steps_done": step + 1 if tc.steps > start_step else start_step}


def _metrics_like(built) -> Dict[str, jnp.ndarray]:
    mshapes = jax.eval_shape(lambda a, b, c: built.fn(a, b, c),
                             *built.abstract_args)[2]
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), mshapes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lossy", default=None,
                    help="gradient compression annotation: topk:R | "
                         "blocktopk:R | int8 (error feedback in opt state)")
    ap.add_argument("--ragged", action="store_true",
                    help="ragged corpus: whole docs + valid_mask, masked stats")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args(argv)
    tc = TrainerConfig(arch=args.arch, smoke=not args.full, steps=args.steps,
                       global_batch=args.batch, seq_len=args.seq,
                       microbatches=args.microbatches, lossy=args.lossy,
                       ragged=args.ragged,
                       model_parallel=args.model_parallel,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    handler = PreemptionHandler()
    out = train(tc, preemption=handler)
    print(f"done: {out['steps_done']} steps")


if __name__ == "__main__":
    main()
