"""Pallas TPU kernel: count-min sketch batch update (paper §3 sketches).

The (depth, width) sketch is the VMEM-resident accumulator (constant
out-block index_map); each grid step hashes a token block with `depth`
universal hashes and scatter-adds via one-hot matmuls on the MXU. The sketch
monoid combine (elementwise +) across devices is one psum — the kernel is
the in-mapper-combining stage of the paper's word-count-with-sketches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.monoids import _HASH_PRIMES


def _uhash_u32(x, seed: int):
    x = x.astype(jnp.uint32)
    a = jnp.uint32(_HASH_PRIMES[seed % len(_HASH_PRIMES)])
    b = jnp.uint32(_HASH_PRIMES[(seed + 3) % len(_HASH_PRIMES)])
    h = (x ^ (x >> 16)) * a
    h = (h ^ (h >> 13)) * b
    return h ^ (h >> 16)


def _cms_kernel(tok_ref, mask_ref, out_ref, *, depth: int, width: int,
                block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    toks = tok_ref[...]
    mask = mask_ref[...].astype(jnp.float32)             # (BN,) 1 for real rows
    rows = []
    for d in range(depth):
        idx = (_uhash_u32(toks, d) % jnp.uint32(width)).astype(jnp.int32)
        onehot = (idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (block_n, width), 1)).astype(jnp.float32)
        # (1, BN) @ (BN, W) on the MXU -> counts for this hash row
        rows.append(jax.lax.dot(mask[None, :], onehot,
                                preferred_element_type=jnp.float32))
    out_ref[...] += jnp.concatenate(rows, axis=0)


def cms_update_pallas(tokens: jnp.ndarray, depth: int, width: int, *,
                      block_n: int = 1024, interpret: bool = True) -> jnp.ndarray:
    """tokens: (N,) int -> (depth, width) float32 counts."""
    N = tokens.shape[0]
    pad = (-N) % block_n
    mask = jnp.ones((N,), jnp.int32)
    if pad:
        tokens = jnp.concatenate([tokens, jnp.zeros((pad,), tokens.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), jnp.int32)])
    grid = ((N + pad) // block_n,)
    return pl.pallas_call(
        functools.partial(_cms_kernel, depth=depth, width=width,
                          block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.float32),
        interpret=interpret,
    )(tokens.astype(jnp.int32), mask)
