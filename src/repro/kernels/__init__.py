"""repro.kernels — Pallas TPU kernels for the paper's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
ops.py (jit'd wrappers), ref.py (pure-jnp oracles). Validated on CPU with
interpret=True + hypothesis shape/dtype sweeps (tests/test_kernels.py).
"""
from .ops import cms_update, flash_attn, mean_by_key, segment_fold, stripes
from . import ref
