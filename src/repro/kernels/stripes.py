"""Pallas TPU kernel: Algorithm 5 ("stripes") co-occurrence accumulation.

The full (V, V) stripe table is the VMEM-resident accumulator; each grid
step takes a block of center tokens plus their pre-gathered window of
neighbors and accumulates one-hot OUTER PRODUCTS on the MXU:

    table += onehot(center)^T @ onehot(neighbor_j)      for each offset j

which is exactly "H{u} += 1 for u in Neighbors(w)" (paper Algorithm 5),
batched into a systolic matmul. The wrapper builds the (N, window) neighbor
matrix so blocks need no halo exchange; vocab is hash-bucketed to V_bucket
(the paper's answer to open key spaces — sketch the tail, §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stripes_kernel(tok_ref, neigh_ref, mask_ref, out_ref, *, vocab: int,
                    window: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    toks = tok_ref[...]                                   # (BN,)
    center = (toks[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_n, vocab), 1)).astype(jnp.float32)
    acc = jnp.zeros((vocab, vocab), jnp.float32)
    for j in range(window):
        nb = neigh_ref[..., j]                            # (BN,)
        valid = mask_ref[..., j].astype(jnp.float32)      # (BN,)
        onehot_nb = (nb[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (block_n, vocab), 1)).astype(jnp.float32)
        onehot_nb = onehot_nb * valid[:, None]
        # (V, BN) @ (BN, V): all BN pair-updates in one MXU pass
        acc += jax.lax.dot(center.T, onehot_nb,
                           preferred_element_type=jnp.float32)
    out_ref[...] += acc + acc.T                           # symmetric relation


def stripes_pallas(tokens: jnp.ndarray, vocab: int, window: int, *,
                   block_n: int = 512, interpret: bool = True) -> jnp.ndarray:
    """tokens: (N,) int -> (vocab, vocab) symmetric co-occurrence counts
    (pairs within distance <= window, both directions)."""
    N = tokens.shape[0]
    # pre-gather forward neighbors: neigh[i, j] = tokens[i + j + 1]
    idx = jnp.arange(N)[:, None] + jnp.arange(1, window + 1)[None, :]
    mask = (idx < N).astype(jnp.int32)
    neigh = tokens[jnp.clip(idx, 0, N - 1)].astype(jnp.int32)
    pad = (-N) % block_n
    toks = tokens.astype(jnp.int32)
    if pad:
        toks = jnp.concatenate([toks, jnp.full((pad,), -1, jnp.int32)])
        neigh = jnp.concatenate(
            [neigh, jnp.full((pad, window), -1, jnp.int32)], axis=0)
        mask = jnp.concatenate([mask, jnp.zeros((pad, window), jnp.int32)], axis=0)
    grid = ((N + pad) // block_n,)
    return pl.pallas_call(
        functools.partial(_stripes_kernel, vocab=vocab, window=window,
                          block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n, window), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, window), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((vocab, vocab), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((vocab, vocab), jnp.float32),
        interpret=interpret,
    )(toks, neigh, mask)
