"""Pallas TPU kernel: flash attention forward = the AttnState monoid in VMEM.

The (m, l, o) online-softmax state (repro.core.monoids.attn_state) is held in
VMEM and folded over KV blocks — in-mapper combining (paper Algorithm 4)
inside the kernel: nothing S^2-sized ever reaches HBM. HBM traffic drops from
O(S^2) score materialization to Q + K + V + O reads/writes, which is the
memory-term reduction claimed in EXPERIMENTS.md §Perf (napkin math there).

Grid: (B*H, Sq/BQ, Sk/BK) — the KV dim is innermost, and the out/m/l blocks'
index_maps are constant in ki, so Pallas keeps them VMEM-resident across the
KV sweep and flushes once per (head, q-block). GQA reads the kv head via
index_map arithmetic (no materialized head repeat). Causality is handled by
masking inside the block; fully-masked blocks contribute the monoid identity.

Block sizes default to (BQ, BK) = (128, 128): q/k/v blocks (128 x d x 4B) +
the f32 (128,128) score tile ~= 260KB at d=128, far under VMEM; MXU dims are
128-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (BQ, d)
    k = k_ref[0].astype(jnp.float32)                     # (BK, d)
    v = v_ref[0].astype(jnp.float32)                     # (BK, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    # fold this block's partial state into (m, l, o) — the attn_state monoid
    m_prev = m_ref[0]                                    # (BQ,)
    l_prev = l_ref[0]
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m_safe[:, None]))
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_ref[0] = l_prev * alpha + p.sum(axis=-1)
    o_ref[0] = o_ref[0] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[0] = m_new


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, d); k, v: (B, KV, Sk, d) with H % KV == 0.

    Returns (B, H, Sq, d) in q's dtype. Forward only (serving / frozen-eval;
    the training path uses the XLA-fused chunked AttnState form, which
    autodiffs — models/attention.py).
    """
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    qf = q.reshape(B * H, Sq, d)
    kf = k.reshape(B * KV, Sk, d)
    vf = v.reshape(B * KV, Sk, d)
    grid = (B * H, Sq // bq, Sk // bk)

    def kv_index(bh, qi, ki):
        return ((bh // H) * KV + (bh % H) // G, ki, 0)

    o, m, l = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, d), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = o / jnp.maximum(l, 1e-30)[..., None]           # the extract()
    return out.reshape(B, H, Sq, d).astype(q.dtype)
