"""Pallas TPU kernel: key-grouped semiring fold (the paper's combiner).

Hadoop's combiner sorts intermediate pairs and streams them; the TPU
adaptation (DESIGN.md §5) instead:

* tiles the record axis N into VMEM-sized blocks (grid dim 0),
* holds the per-key accumulator table (S, D) RESIDENT IN VMEM across grid
  steps — in-mapper combining *inside the kernel* (the output block's
  index_map is constant, so Pallas keeps one live copy),
* turns the scatter into a one-hot (S, BN) x (BN, D) matmul so the combine
  runs on the MXU systolic array (a serialized scatter would be VPU-bound —
  napkin math: BN=512, S=512, D=512 => 1.3e8 MACs/block vs 2.6e5 serial VPU
  adds; the MXU path is ~500x denser).

The kernel is parameterized by semiring, so one lowering path serves the
whole additive/max-plus monoid family the planner (core/plan.py) registers:

* ``'sum'``  — the additive monoids (sum / count / stripes / mean's
  (sum, count) pair): one-hot matmul on the MXU.  ``with_count=True``
  appends a ones column so mean's two components ride one matmul.
* ``'max'`` / ``'min'`` — the max-plus family (max, min, and 0/1-bitmap
  bitwise_or): the one-hot mask selects block rows per segment and the VPU
  takes the running max/min.  This path materializes an (S, BN, D) select,
  so prefer a smaller ``block_n`` than the additive default.

Exact integer monoids round-trip: integer inputs are accumulated in float32
(exact for |values| < 2**24) and cast back to the input dtype, with empty
max/min segments mapped to the dtype's min/max (the integer identity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEMIRINGS = ("sum", "max", "min")

_IDENTITY = {"sum": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _segment_fold_kernel(seg_ref, val_ref, out_ref, *, num_segments: int,
                         block_n: int, semiring: str):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _IDENTITY[semiring])

    seg = seg_ref[...]                                   # (BN,)
    vals = val_ref[...].astype(jnp.float32)              # (BN, D)
    # one-hot scatter mask: padded rows carry seg id == num_segments (out of
    # range), so they match no row and contribute the semiring identity.
    mask = seg[None, :] == jax.lax.broadcasted_iota(
        jnp.int32, (num_segments, block_n), 0)           # (S, BN)
    if semiring == "sum":
        out_ref[...] += jax.lax.dot(mask.astype(jnp.float32), vals,
                                    preferred_element_type=jnp.float32)
    else:
        picked = jnp.where(mask[:, :, None], vals[None, :, :],
                           _IDENTITY[semiring])          # (S, BN, D) on the VPU
        if semiring == "max":
            out_ref[...] = jnp.maximum(out_ref[...], picked.max(axis=1))
        else:
            out_ref[...] = jnp.minimum(out_ref[...], picked.min(axis=1))


def _finish_dtype(out: jnp.ndarray, dtype, semiring: str) -> jnp.ndarray:
    """Cast the float32 accumulator back for exact integer monoids.

    Floating inputs keep the float32 accumulator (the historical contract);
    integer inputs round-trip, with ±inf (empty max/min segments) mapped to
    the integer identity iinfo.min/max — matching jax.ops.segment_max/min.
    """
    if not jnp.issubdtype(dtype, jnp.integer):
        return out
    info = jnp.iinfo(dtype)
    if semiring == "max":
        out = jnp.where(jnp.isneginf(out), float(info.min), out)
    elif semiring == "min":
        out = jnp.where(jnp.isposinf(out), float(info.max), out)
    return out.astype(dtype)


def segment_fold_pallas(values: jnp.ndarray, seg_ids: jnp.ndarray,
                        num_segments: int, *, block_n: int = 512,
                        semiring: str = "sum", with_count: bool = False,
                        valid_mask: jnp.ndarray | None = None,
                        interpret: bool | None = None):
    """values: (N, D); seg_ids: (N,) int32 in [0, num_segments).

    Returns the (S, D) semiring fold — or ((S, D) sums, (S,) counts) with
    ``with_count`` (additive semiring only).  N is padded to a block multiple
    with the out-of-range segment id ``num_segments``, which folds into no
    real segment — the semiring identity contributes nothing.

    ``valid_mask`` (N,) bool makes the fold ragged: invalid rows are routed
    to the same out-of-range segment id the padding uses, so they contribute
    the semiring identity — no rectangular batch required, and the kernel
    body is untouched (one mask per grid step, zero extra FLOPs on the MXU).

    ``interpret=None`` resolves via :func:`repro.kernels.ops._default_interpret`
    (TPU detection, overridable with ``REPRO_INTERPRET=0/1``).
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; one of {SEMIRINGS}")
    if valid_mask is not None:
        seg_ids = jnp.where(jnp.asarray(valid_mask, jnp.bool_),
                            seg_ids, num_segments)
    if interpret is None:
        from .ops import _default_interpret
        interpret = _default_interpret()
    orig_dtype = values.dtype
    N, D = values.shape
    if with_count:
        if semiring != "sum":
            raise ValueError("with_count requires the additive semiring")
        values = jnp.concatenate(
            [values.astype(jnp.float32), jnp.ones((N, 1), jnp.float32)], axis=1)
        D += 1
    pad = (-N) % block_n
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((pad, D), values.dtype)], axis=0)
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((pad,), num_segments, seg_ids.dtype)], axis=0)
    grid = ((N + pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_segment_fold_kernel, num_segments=num_segments,
                          block_n=block_n, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), values)
    if with_count:
        return _finish_dtype(out[:, :-1], orig_dtype, semiring), out[:, -1]
    return _finish_dtype(out, orig_dtype, semiring)
