"""Pallas TPU kernel: key-grouped monoid fold (the paper's combiner).

Hadoop's combiner sorts intermediate pairs and streams them; the TPU
adaptation (DESIGN.md §5) instead:

* tiles the record axis N into VMEM-sized blocks (grid dim 0),
* holds the per-key accumulator table (S, D) RESIDENT IN VMEM across grid
  steps — in-mapper combining *inside the kernel* (the output block's
  index_map is constant, so Pallas keeps one live copy),
* turns the scatter into a one-hot (S, BN) x (BN, D) matmul so the combine
  runs on the MXU systolic array (a serialized scatter would be VPU-bound —
  napkin math: BN=512, S=512, D=512 => 1.3e8 MACs/block vs 2.6e5 serial VPU
  adds; the MXU path is ~500x denser).

The additive monoids (sum / count / mean's (sum,count) pair) are exactly the
paper's running example; `with_count=True` appends a ones column so mean's
two components ride one matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_fold_kernel(seg_ref, val_ref, out_ref, *, num_segments: int,
                         block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                                   # (BN,)
    vals = val_ref[...].astype(jnp.float32)              # (BN, D)
    # one-hot scatter as an MXU matmul: (S, BN) @ (BN, D)
    onehot = (seg[None, :] == jax.lax.broadcasted_iota(
        jnp.int32, (num_segments, block_n), 0)).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(onehot, vals,
                                preferred_element_type=jnp.float32)


def segment_fold_pallas(values: jnp.ndarray, seg_ids: jnp.ndarray,
                        num_segments: int, *, block_n: int = 512,
                        with_count: bool = False, interpret: bool = True):
    """values: (N, D); seg_ids: (N,) int32 in [0, num_segments).

    Returns (S, D) sums — or ((S, D) sums, (S,) counts) with with_count.
    N is padded to a block multiple with an out-of-range segment id (folded
    into no real segment — the monoid identity contributes nothing).
    """
    N, D = values.shape
    if with_count:
        values = jnp.concatenate(
            [values.astype(jnp.float32), jnp.ones((N, 1), jnp.float32)], axis=1)
        D += 1
    pad = (-N) % block_n
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((pad, D), values.dtype)], axis=0)
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.zeros((pad,), seg_ids.dtype)], axis=0)
        # padded rows are zeros: they add identity to segment 0
    grid = ((N + pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_segment_fold_kernel, num_segments=num_segments,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        interpret=interpret,
    )(seg_ids.astype(jnp.int32), values)
    if with_count:
        return out[:, :-1], out[:, -1]
    return out
