"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU — pl.pallas_call + BlockSpec VMEM tiling — and are
validated in interpret mode against the ref.py oracles).  Set
``REPRO_INTERPRET=1`` to force interpret mode on TPU (debugging) or
``REPRO_INTERPRET=0`` to force compiled mode in CI; the override is read
when the wrapper is called (i.e. at trace time for jit'd callers).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from .segment_fold import segment_fold_pallas
from .cms import cms_update_pallas
from .stripes import stripes_pallas
from .flash_attention import flash_attention

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def _default_interpret() -> bool:
    """Interpret-mode default: backend detection, REPRO_INTERPRET override."""
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_segments", "with_count", "block_n",
                                   "semiring", "interpret"))
def _segment_fold_jit(values, seg_ids, num_segments, with_count, block_n,
                      semiring, interpret):
    return segment_fold_pallas(values, seg_ids, num_segments,
                               with_count=with_count, block_n=block_n,
                               semiring=semiring, interpret=interpret)


def segment_fold(values: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int,
                 *, with_count: bool = False, block_n: int = 512,
                 semiring: str = "sum", interpret: bool | None = None):
    """MXU-tiled key-grouped semiring fold: the paper's combiner.

    semiring='sum' (default) is the additive family; 'max'/'min' serve the
    max-plus monoids.  Exact integer inputs round-trip to their dtype.
    """
    if interpret is None:
        interpret = _default_interpret()
    return _segment_fold_jit(values, seg_ids, num_segments, with_count,
                             block_n, semiring, interpret)


@partial(jax.jit, static_argnames=("num_segments", "block_n", "interpret"))
def _mean_by_key_jit(values, seg_ids, num_segments, block_n, interpret):
    sums, counts = segment_fold_pallas(values, seg_ids, num_segments,
                                       with_count=True, block_n=block_n,
                                       interpret=interpret)
    return sums / jnp.maximum(counts, 1.0)[:, None]


def mean_by_key(values: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int,
                *, block_n: int = 512,
                interpret: bool | None = None) -> jnp.ndarray:
    """The paper's running example, kernel edition: extract(sum/count)."""
    if interpret is None:
        interpret = _default_interpret()
    return _mean_by_key_jit(values, seg_ids, num_segments, block_n, interpret)


@partial(jax.jit, static_argnames=("depth", "width", "block_n"))
def cms_update(tokens: jnp.ndarray, depth: int = 4, width: int = 2048,
               *, block_n: int = 1024) -> jnp.ndarray:
    return cms_update_pallas(tokens, depth, width, block_n=block_n,
                             interpret=_default_interpret())


@partial(jax.jit, static_argnames=("vocab", "window", "block_n"))
def stripes(tokens: jnp.ndarray, vocab: int, window: int,
            *, block_n: int = 512) -> jnp.ndarray:
    return stripes_pallas(tokens, vocab, window, block_n=block_n,
                          interpret=_default_interpret())


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
               causal: bool = True, block_q: int = 128,
               block_k: int = 128) -> jnp.ndarray:
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=_default_interpret())
