"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU — pl.pallas_call + BlockSpec VMEM tiling — and are
validated in interpret mode against the ref.py oracles).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .segment_fold import segment_fold_pallas
from .cms import cms_update_pallas
from .stripes import stripes_pallas
from .flash_attention import flash_attention


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_segments", "with_count", "block_n"))
def segment_fold(values: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int,
                 *, with_count: bool = False, block_n: int = 512):
    """MXU-tiled key-grouped sum (and count): the paper's combiner."""
    return segment_fold_pallas(values, seg_ids, num_segments,
                               with_count=with_count, block_n=block_n,
                               interpret=_default_interpret())


@partial(jax.jit, static_argnames=("num_segments", "block_n"))
def mean_by_key(values: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int,
                *, block_n: int = 512) -> jnp.ndarray:
    """The paper's running example, kernel edition: extract(sum/count)."""
    sums, counts = segment_fold_pallas(values, seg_ids, num_segments,
                                       with_count=True, block_n=block_n,
                                       interpret=_default_interpret())
    return sums / jnp.maximum(counts, 1.0)[:, None]


@partial(jax.jit, static_argnames=("depth", "width", "block_n"))
def cms_update(tokens: jnp.ndarray, depth: int = 4, width: int = 2048,
               *, block_n: int = 1024) -> jnp.ndarray:
    return cms_update_pallas(tokens, depth, width, block_n=block_n,
                             interpret=_default_interpret())


@partial(jax.jit, static_argnames=("vocab", "window", "block_n"))
def stripes(tokens: jnp.ndarray, vocab: int, window: int,
            *, block_n: int = 512) -> jnp.ndarray:
    return stripes_pallas(tokens, vocab, window, block_n=block_n,
                          interpret=_default_interpret())


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
               causal: bool = True, block_q: int = 128,
               block_k: int = 128) -> jnp.ndarray:
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=_default_interpret())
