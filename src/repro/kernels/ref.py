"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core import monoids


def segment_fold_ref(values: jnp.ndarray, seg_ids: jnp.ndarray,
                     num_segments: int, *, with_count: bool = False):
    """Sum (and optional count) of values by segment id. values: (N, D)."""
    sums = jax.ops.segment_sum(values.astype(jnp.float32), seg_ids,
                               num_segments=num_segments)
    if not with_count:
        return sums
    counts = jax.ops.segment_sum(jnp.ones((values.shape[0],), jnp.float32),
                                 seg_ids, num_segments=num_segments)
    return sums, counts


def cms_update_ref(tokens: jnp.ndarray, depth: int, width: int) -> jnp.ndarray:
    """Count-min sketch of a token batch (int32 counts)."""
    sketch = jnp.zeros((depth, width), jnp.int32)
    return monoids.cms_update_batch(sketch, tokens)


def stripes_ref(tokens: jnp.ndarray, vocab: int, window: int) -> jnp.ndarray:
    """Symmetric co-occurrence counts within +-window (Algorithm 5)."""
    return monoids.cooccurrence_stripes(tokens, vocab, window)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True) -> jnp.ndarray:
    """Plain softmax attention. q: (B,H,Sq,d); k,v: (B,KV,Sk,d); GQA by
    head-group broadcast."""
    B, H, Sq, d = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, d)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        Sk = k.shape[2]
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)
