"""repro.optim — optimizer substrate (AdamW, schedules, grad compression)."""
from .adamw import (OptConfig, adamw_update, clip_by_global_norm, global_norm,
                    init_opt_state, opt_state_shapes, schedule)
