"""repro.optim — optimizer substrate (AdamW, schedules, grad compression)."""
from .adamw import (OptConfig, adamw_update, clip_by_global_norm, global_norm,
                    init_opt_state, opt_state_shapes, schedule)
from .compress import (LossySpec, blocktopk_compress, compressed_bytes,
                       init_error_state, int8_compress, int8_decompress,
                       int8_sum_monoid, topk_compress, topk_decompress,
                       topk_sparse_monoid)
