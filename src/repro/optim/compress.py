"""Gradient compression with error feedback (EF14-style), and the lossy
monoids that let compressed representations ride the planner's folds.

At multi-pod scale the cross-pod (DCN) all-reduce is the scarce resource.
Three compressors reduce the bytes a gradient puts on the slow wire:

* ``topk``      — keep the k largest-|g| entries per leaf (values + int32 idx).
* ``blocktopk`` — keep the largest-|g| entry of each contiguous block (same
  sparse representation, O(n) selection instead of a sort — the cheap spelling
  for huge leaves and for per-microbatch compression in the async tier).
* ``int8``      — per-leaf symmetric scale quantization.

All use error feedback: e_{t+1} = (g + e_t) - decompress(compress(g + e_t)),
so the *sum over steps* of applied updates converges to the sum of true
gradients — the residual rides the gradient Sum monoid rather than being
dropped (this is why EF converges where plain top-k diverges).  The residual
is computed against what the receiver will actually apply, including the cast
back to the parameter dtype, so EF stays exact for bf16 params.

The compressed representations themselves combine as monoids:

* sparse sets combine by concatenation + re-top-k (:func:`topk_sparse_monoid`,
  fixed capacity k) — how a hierarchical DCN reduction combines pod-level
  sparse gradients without densifying;
* int8 tensors combine by dequantize-add-requantize
  (:func:`int8_sum_monoid`) — associative up to quantization error, which the
  monoid's ``approx_equal`` bounds by the operand scales.

Both are registered in the monoid registry with law samples, so the CI
monoid-law step checks them like every other monoid.

:class:`LossySpec` is the planner-facing annotation: parse ``"topk:0.01"`` /
``"blocktopk:0.001"`` / ``"int8"`` and get compress/decompress/wire-byte
accounting as one object (``execute_fold(..., lossy=...)``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.monoid import Monoid
from ..core.monoids import register_monoid

Pytree = Any

LOSSY_METHODS = ("topk", "blocktopk", "int8")


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _clamp_k(size: int, ratio: float) -> int:
    """k for a leaf of ``size`` entries: never 0, never more than the leaf
    holds (a ratio on a tiny leaf used to be able to request either)."""
    return max(1, min(int(size * ratio), size))


def _block_shape(size: int, ratio: float) -> Tuple[int, int]:
    """(num_blocks k, block length) for blocktopk: one survivor per block."""
    blk = max(1, int(round(1.0 / max(ratio, 1e-12))))
    blk = min(blk, size)
    return -(-size // blk), blk          # ceil(size / blk), blk


def _ef_residual(acc: jnp.ndarray, idx: jnp.ndarray, kept: jnp.ndarray,
                 out_dtype) -> jnp.ndarray:
    """Residual of ``acc`` after the receiver applies ``kept`` at ``idx``.

    The receiver decompresses into ``out_dtype`` (the parameter dtype), so
    what lands is ``kept`` *after* that cast — for bf16 params the rounding
    difference must stay in the error state or EF silently leaks mass.
    """
    applied = kept.astype(out_dtype).astype(jnp.float32)
    return acc.at[idx].set(acc[idx] - applied)


# -- top-k -------------------------------------------------------------------

def topk_compress(grads: Pytree, error: Pytree, *, ratio: float = 0.01
                  ) -> Tuple[Pytree, Pytree]:
    """-> (sparse {values, idx, size} per leaf, new error state)."""
    def one(g, e):
        acc = g.astype(jnp.float32).reshape(-1) + e.reshape(-1)
        k = _clamp_k(acc.size, ratio)
        _, idx = jax.lax.top_k(jnp.abs(acc), k)
        kept = acc[idx]
        new_e = _ef_residual(acc, idx, kept, g.dtype).reshape(e.shape)
        return {"values": kept, "idx": idx.astype(jnp.int32),
                "size": acc.size}, new_e

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(leaves, eleaves)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_error = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return comp, new_error


def blocktopk_compress(grads: Pytree, error: Pytree, *, ratio: float = 0.01
                       ) -> Tuple[Pytree, Pytree]:
    """Top-1-per-block selection: the O(n) top-k for huge leaves.

    Same sparse {values, idx, size} representation as :func:`topk_compress`,
    but the survivors are the largest-|g| entry of each contiguous block of
    ~1/ratio entries — one vectorized argmax pass instead of a sort, which
    is what makes per-microbatch compression affordable inside the async
    tier's double-buffered scan.
    """
    def one(g, e):
        acc = g.astype(jnp.float32).reshape(-1) + e.reshape(-1)
        k, blk = _block_shape(acc.size, ratio)
        padded = jnp.pad(acc, (0, k * blk - acc.size)).reshape(k, blk)
        j = jnp.argmax(jnp.abs(padded), axis=1)
        kept = jnp.take_along_axis(padded, j[:, None], axis=1)[:, 0]
        idx = jnp.minimum(jnp.arange(k) * blk + j, acc.size - 1).astype(jnp.int32)
        new_e = _ef_residual(acc, idx, kept, g.dtype).reshape(e.shape)
        return {"values": kept, "idx": idx, "size": acc.size}, new_e

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(leaves, eleaves)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))


def topk_decompress(comp: Pytree, like: Pytree) -> Pytree:
    def one(c, g):
        flat = jnp.zeros((c["size"],), jnp.float32).at[c["idx"]].add(c["values"])
        return flat.reshape(g.shape).astype(g.dtype)
    return jax.tree_util.tree_map(
        one, comp, like,
        is_leaf=lambda x: isinstance(x, dict) and "values" in x)


# -- int8 ---------------------------------------------------------------------

def int8_compress(grads: Pytree, error: Pytree) -> Tuple[Pytree, Pytree]:
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(acc)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
        # residual vs what the receiver applies AFTER casting to g.dtype
        applied = (q.astype(jnp.float32) * scale).astype(g.dtype).astype(jnp.float32)
        return {"q": q, "scale": scale}, acc - applied
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(leaves, eleaves)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))


def int8_decompress(comp: Pytree, like: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda c, g: (c["q"].astype(jnp.float32) * c["scale"]).astype(g.dtype),
        comp, like, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_bytes(comp: Pytree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(comp):
        if hasattr(leaf, "dtype"):   # skip python-int metadata ("size")
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return int(total)


# ---------------------------------------------------------------------------
# lossy monoids: the compressed representations ARE monoid values
# ---------------------------------------------------------------------------

def _sparse_key(vals: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Selection key for re-top-k: |value|, with padding (idx < 0) at -inf
    so real entries always out-rank unused capacity."""
    return jnp.where(idx < 0, -jnp.inf, jnp.abs(vals))


def _sparse_canon(s) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Canonical (values, idx) ordering — entry multisets compare equal
    regardless of which bracketing produced them."""
    order = jnp.lexsort((s["values"], s["idx"]))
    return s["values"][order], s["idx"][order]


def topk_sparse_monoid(k: int) -> Monoid:
    """Fixed-capacity sparse gradients under concatenate + re-top-k.

    Values are ``{"values": (k,) f32, "idx": (k,) i32}`` with idx -1 marking
    unused capacity.  Combining keeps the k largest-|value| entries of the
    union; duplicate indices stay as separate entries (densify with
    scatter-ADD, so the fold is still a sum).  Exact while total real entries
    fit in k; beyond that it is *lossy* — the truncated mass is what error
    feedback exists to recover.
    """
    def combine(a, b):
        v = jnp.concatenate([a["values"], b["values"]], axis=-1)
        i = jnp.concatenate([a["idx"], b["idx"]], axis=-1)
        _, pick = jax.lax.top_k(_sparse_key(v, i), k)
        return {"values": v[pick], "idx": i[pick]}

    def identity_fn(*, example=None):
        return {"values": jnp.zeros((k,), jnp.float32),
                "idx": jnp.full((k,), -1, jnp.int32)}

    def approx_equal(a, b):
        va, ia = _sparse_canon(a)
        vb, ib = _sparse_canon(b)
        return bool(jnp.all(ia == ib)
                    and jnp.allclose(va, vb, rtol=1e-5, atol=1e-6))

    return Monoid(name=f"lossy_topk{k}", combine=combine,
                  identity_fn=identity_fn, approx_equal=approx_equal)


def int8_sum_monoid() -> Monoid:
    """Quantized tensors under dequantize-add-requantize.

    Values are ``{"q": int8, "scale": f32 ()}``.  Associative up to one
    quantization step per combine; ``approx_equal`` compares dequantized
    tensors within a tolerance set by the operand scales.  The identity
    (q=0, scale=0) is exact, and canonical states (those produced by
    ``int8_compress``, where max|q| == 127) round-trip exactly.
    """
    def deq(s):
        return s["q"].astype(jnp.float32) * s["scale"]

    def combine(a, b):
        total = deq(a) + deq(b)
        scale = jnp.maximum(jnp.max(jnp.abs(total)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(total / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    def identity_fn(*, example=None):
        if example is None:
            raise ValueError("int8_sum_monoid identity needs an example "
                             "(shape-polymorphic); use identity_like")
        return {"q": jnp.zeros_like(example["q"]),
                "scale": jnp.zeros_like(example["scale"])}

    def approx_equal(a, b):
        atol = 2.0 * float(a["scale"] + b["scale"]) + 1e-6
        return bool(jnp.allclose(deq(a), deq(b), rtol=0.0, atol=atol))

    return Monoid(name="lossy_int8", combine=combine, identity_fn=identity_fn,
                  approx_equal=approx_equal)


def _lossy_topk_samples():
    m = topk_sparse_monoid(8)
    def entry(vals, idxs):
        s = m.identity()
        v = s["values"].at[:2].set(jnp.asarray(vals, jnp.float32))
        i = s["idx"].at[:2].set(jnp.asarray(idxs, jnp.int32))
        return {"values": v, "idx": i}
    # 2 entries per sample: 3 samples total 6 <= capacity 8, so the law
    # check exercises the EXACT regime (truncation loss is EF's job, not
    # associativity's)
    return [entry((3.0, -1.5), (7, 2)), entry((0.25, 4.0), (1, 5)),
            entry((-2.0, 0.75), (9, 0))]


def _lossy_int8_samples():
    import numpy as np
    out = []
    for seed in (0, 1, 2):
        x = jnp.asarray(np.random.default_rng(seed).normal(size=(16,))
                        .astype(np.float32))
        comp, _ = int8_compress(x, jnp.zeros_like(x))
        out.append(comp)
    return out


register_monoid(topk_sparse_monoid(8), _lossy_topk_samples)
register_monoid(int8_sum_monoid(), _lossy_int8_samples)


# ---------------------------------------------------------------------------
# LossySpec — the planner-facing annotation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LossySpec:
    """A parsed ``lossy=`` annotation: which compressor, how aggressive.

    Accepted spellings (``LossySpec.parse``): ``"topk:0.01"``,
    ``"blocktopk:0.001"``, ``"int8"`` — or an existing LossySpec.
    """

    method: str
    ratio: float = 0.01

    def __post_init__(self):
        if self.method not in LOSSY_METHODS:
            raise ValueError(f"unknown lossy method {self.method!r}; "
                             f"expected one of {LOSSY_METHODS}")
        if not (0.0 < self.ratio <= 1.0):
            raise ValueError(f"lossy ratio must be in (0, 1]; got {self.ratio}")

    @classmethod
    def parse(cls, spec) -> "LossySpec":
        if isinstance(spec, LossySpec):
            return spec
        if not isinstance(spec, str):
            raise TypeError(f"lossy= expects a string or LossySpec; got "
                            f"{type(spec).__name__}")
        method, _, arg = spec.partition(":")
        if method == "int8":
            return cls(method="int8", ratio=1.0)
        return cls(method=method, ratio=float(arg) if arg else 0.01)

    def describe(self) -> str:
        if self.method == "int8":
            return "int8"
        return f"{self.method}:{self.ratio:g}"

    # -- compress / decompress ----------------------------------------------
    def compress(self, grads: Pytree, error: Optional[Pytree] = None
                 ) -> Tuple[Pytree, Pytree]:
        if error is None:
            error = init_error_state(grads)
        if self.method == "topk":
            return topk_compress(grads, error, ratio=self.ratio)
        if self.method == "blocktopk":
            return blocktopk_compress(grads, error, ratio=self.ratio)
        return int8_compress(grads, error)

    def decompress(self, comp: Pytree, like: Pytree) -> Pytree:
        if self.method == "int8":
            return int8_decompress(comp, like)
        return topk_decompress(comp, like)

    # -- byte accounting (shape-only; works on ShapeDtypeStructs) ------------
    def leaf_wire_bytes(self, size: int) -> int:
        if self.method == "int8":
            return size * 1 + 4
        if self.method == "blocktopk":
            k, _ = _block_shape(size, self.ratio)
        else:
            k = _clamp_k(size, self.ratio)
        return k * 8          # f32 value + i32 index per survivor

    def wire_bytes(self, like: Pytree) -> int:
        return int(sum(self.leaf_wire_bytes(int(math.prod(leaf.shape)) or 1)
                       for leaf in jax.tree_util.tree_leaves(like)))
