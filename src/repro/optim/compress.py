"""Gradient compression with error feedback (EF14-style).

At multi-pod scale the cross-pod (DCN) all-reduce is the scarce resource.
Two compressors reduce the bytes a gradient puts on the slow wire:

* ``topk``  — keep the k largest-|g| entries per leaf (values + int32 idx).
* ``int8``  — per-leaf symmetric scale quantization.

Both use error feedback: e_{t+1} = (g + e_t) - decompress(compress(g + e_t)),
so the *sum over steps* of applied updates converges to the sum of true
gradients — the residual rides the gradient Sum monoid rather than being
dropped (this is why EF converges where plain top-k diverges).

The compressed representation of top-k is itself monoid-friendly: two sparse
(values, idx) sets combine by concatenation + re-top-k
(``repro.core.monoids.top_k``), which is how a hierarchical DCN reduction
would combine pod-level sparse gradients without densifying.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


# -- top-k -------------------------------------------------------------------

def topk_compress(grads: Pytree, error: Pytree, *, ratio: float = 0.01
                  ) -> Tuple[Pytree, Pytree]:
    """-> (sparse {values, idx, size} per leaf, new error state)."""
    def one(g, e):
        acc = g.astype(jnp.float32).reshape(-1) + e.reshape(-1)
        k = max(1, int(acc.size * ratio))
        vals, idx = jax.lax.top_k(jnp.abs(acc), k)
        kept = acc[idx]
        new_e = acc.at[idx].set(0.0).reshape(e.shape)
        return {"values": kept, "idx": idx.astype(jnp.int32),
                "size": acc.size}, new_e

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(leaves, eleaves)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_error = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return comp, new_error


def topk_decompress(comp: Pytree, like: Pytree) -> Pytree:
    def one(c, g):
        flat = jnp.zeros((c["size"],), jnp.float32).at[c["idx"]].set(c["values"])
        return flat.reshape(g.shape).astype(g.dtype)
    return jax.tree_util.tree_map(
        one, comp, like,
        is_leaf=lambda x: isinstance(x, dict) and "values" in x)


# -- int8 ---------------------------------------------------------------------

def int8_compress(grads: Pytree, error: Pytree) -> Tuple[Pytree, Pytree]:
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(acc)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return {"q": q, "scale": scale}, acc - deq
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    eleaves = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(leaves, eleaves)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))


def int8_decompress(comp: Pytree, like: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda c, g: (c["q"].astype(jnp.float32) * c["scale"]).astype(g.dtype),
        comp, like, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_bytes(comp: Pytree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(comp):
        if hasattr(leaf, "dtype"):   # skip python-int metadata ("size")
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return int(total)
