"""AdamW with mixed precision, global-norm clipping and warmup-cosine schedule.

Monoid hooks (the paper's §3 SGD observation, generalized):

* Gradients are a Sum monoid over microbatches and over data-parallel shards
  — accumulation order is free, which is what makes grad-accumulation scans
  (:func:`repro.core.aggregation.grad_accum_fold`) and hierarchical
  cross-pod reduction legal.
* The optimizer *state* (m, v) is NOT a monoid in the update — Adam's
  normalizer is order-sensitive — but parameter *deltas* under addition are,
  which is what the error-feedback compression in ``optim/compress.py``
  exploits.

Master weights / m / v are fp32, sharded exactly like the bf16 params (the
TRAIN_RULES already 2D-shard big tensors over (data, model), so optimizer
state is ZeRO-sharded 256-way for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params: Pytree, *, with_ef: bool = False) -> Dict[str, Pytree]:
    """with_ef adds the error-feedback residual of a ``lossy=`` grad fold
    (see optim/compress.py) — fold state that must persist across steps, so
    it lives (and checkpoints) with the optimizer state."""
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": f32(params),
        "v": f32(params),
        "master": jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), params),
    }
    if with_ef:
        state["ef"] = f32(params)
    return state


def opt_state_shapes(param_shapes: Pytree, *, with_ef: bool = False
                     ) -> Dict[str, Pytree]:
    """Abstract opt state (dry-run path)."""
    f32 = lambda t: jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    state = {"step": jax.ShapeDtypeStruct((), jnp.int32),
             "m": f32(param_shapes), "v": f32(param_shapes),
             "master": f32(param_shapes)}
    if with_ef:
        state["ef"] = f32(param_shapes)
    return state


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads: Pytree, opt_state: Dict[str, Pytree], cfg: OptConfig,
                 *, grad_scale: float = 1.0
                 ) -> Tuple[Pytree, Dict[str, Pytree], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new bf16-cast params, new state, opt metrics).

    grad_scale divides the summed gradients (the `extract` of the grad-Sum
    monoid — e.g. 1/num_microbatches after grad_accum_fold).
    """
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * grad_scale, grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(m, v, g, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return m, v, p - lr * delta

    flat_m, treedef = jax.tree_util.tree_flatten(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_p = jax.tree_util.tree_leaves(opt_state["master"])
    out = [upd(m, v, g, p) for m, v, g, p in zip(flat_m, flat_v, flat_g, flat_p)]
    new_m = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), new_master)
    new_state = {"step": step, "m": new_m, "v": new_v, "master": new_master}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
