"""repro.serving — the stable public serving facade.

Continuous batching as the paper's monoid principle applied to inference:
requests roll through a fixed population of slots, per-request aggregates
fold through ONE planner-lowered keyed masked fold per decode step
(request slot == segment id), and compilation is bounded by a declared
prefill-bucket ladder.

  from repro.serving import ContinuousEngine, ServeConfig, build_engine

  engine = build_engine(ServeConfig(arch="qwen3-0.6b", num_slots=4,
                                    prefill_buckets=(8, 16)))
  uid = engine.submit([1, 17, 42], max_new_tokens=8)
  for event in engine.run():        # StreamEvents as tokens decode
      ...

The engine itself is model-agnostic (``repro.runtime.engine``); this
module is the import surface applications should depend on —
``build_engine`` wires the real model substrate, and the engine classes,
the request/stream types, and the admission-queue types are all here.
"""
from ..data.windows import WindowedMetrics
from ..launch.serve import build_engine, build_serve_step, run_batched_decode
from ..runtime.batcher import BatcherStats, DecodeBatch, Request, RequestBatcher
from ..runtime.engine import (ContinuousEngine, EngineBackend, EngineStats,
                              METRIC_COLS, RequestResult, ServeConfig,
                              StreamEvent, decode_metrics_init,
                              decode_metrics_plan, decode_metrics_step,
                              extract_metrics)
from ..runtime.prefix_cache import (PrefixCache, PrefixCacheConfig,
                                    PrefixCacheStats, PrefixHit)

__all__ = [
    "BatcherStats", "ContinuousEngine", "DecodeBatch", "EngineBackend",
    "EngineStats", "METRIC_COLS", "PrefixCache", "PrefixCacheConfig",
    "PrefixCacheStats", "PrefixHit", "Request", "RequestBatcher",
    "RequestResult", "ServeConfig", "StreamEvent", "WindowedMetrics",
    "build_engine", "build_serve_step", "decode_metrics_init",
    "decode_metrics_plan", "decode_metrics_step", "extract_metrics",
    "run_batched_decode",
]
