"""repro.runtime — continuous-batching serve engine, request batcher, and
fault tolerance (preemption, elastic re-mesh, stragglers)."""
from .batcher import BatcherStats, DecodeBatch, Request, RequestBatcher
from .engine import (ContinuousEngine, EngineBackend, EngineStats,
                     RequestResult, ServeConfig, StreamEvent,
                     decode_metrics_init, decode_metrics_plan,
                     decode_metrics_step, extract_metrics)
from .fault_tolerance import (ElasticController, MeshPlan, PreemptionHandler,
                              StragglerMonitor, StragglerReport,
                              checkpoint_interval, plan_remesh)
from .prefix_cache import (PrefixCache, PrefixCacheConfig, PrefixCacheStats,
                           PrefixHit)
