"""repro.runtime — serving batcher + fault tolerance (preemption, elastic
re-mesh, stragglers)."""
from .batcher import BatcherStats, DecodeBatch, Request, RequestBatcher
from .fault_tolerance import (ElasticController, MeshPlan, PreemptionHandler,
                              StragglerMonitor, StragglerReport,
                              checkpoint_interval, plan_remesh)
