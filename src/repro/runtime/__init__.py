"""repro.runtime — fault tolerance: preemption, elastic re-mesh, stragglers."""
from .fault_tolerance import (ElasticController, MeshPlan, PreemptionHandler,
                              StragglerMonitor, StragglerReport,
                              checkpoint_interval, plan_remesh)
