"""Continuous-batching serve engine: rolling request slots, bucketed
compilation, streaming decode.

The paper's point is that monoid aggregation states can be merged
incrementally, anywhere, at any time — which is precisely what a
continuously-batched decode loop needs.  A fixed batch decoded to
completion (PR 3's ``run_batched_decode``) wastes every slot whose request
finished early; here a freed slot (= segment id, the planner's keyed-fold
key) is handed to the next waiting request *mid-decode*, and the
per-request metrics keep folding through the SAME keyed masked fold
(:func:`decode_metrics_step`) over the rolling slot population — the fold
never needs to know a slot changed hands, because the running table is just
a monoid value re-bracketed across admissions (``init=`` carries it).

Compilation is bucketed so slot churn never recompiles anything:

* ONE decode-step program at ``(num_slots, 1)`` — model forward + per-row
  sampling + the keyed masked metrics fold, jitted together.
* ONE prefill program per ``(k, bucket)`` pair — up to k same-bucket
  admissions ``lax.scan`` the decode step together over their prompts
  padded to the bucket, against a fresh k-row cache whose first ``slab``
  rows were scattered from the radix prefix cache
  (``runtime/prefix_cache.py``) so only the uncached SUFFIX is computed
  (buckets are chosen on suffix length).
* ONE slot-write program per k — scatter the prefilled k-row cache into
  the rolling cache at the freed slots (resetting their metrics rows) —
  and, with the prefix cache on, ONE gather program per k that slices the
  first ``slab`` KV rows back out for the trie.

So the number of distinct jitted shapes is bounded by
:meth:`ContinuousEngine.compile_bound` for the whole engine lifetime (the
recompile-count test in tests/test_serving.py asserts this).  Padding to
the nearest bucket trades bounded extra prefill FLOPs for zero recompiles —
the external-memory cost-model trade (Greiner & Jacob, PAPERS.md): pay
predictable padding, never pay compilation.

Slot independence is guaranteed by the model layer's per-slot cache
positions (``init_cache(pos_per_slot=True)``): each row writes and masks
its KV at its own position, so a reused slot's computation is bit-identical
to the same request decoded alone.

The engine is model-agnostic: it drives an :class:`EngineBackend` (a
traceable decode function + cache constructor), so the whole slot/admission
machinery is testable without a model.  ``repro.launch.serve.build_engine``
wires the real model substrate; the stable import surface is
``repro.serving``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import monoids
from ..core.plan import Plan, execute_fold, plan_fold
from ..models.attention import cache_span_update
from .batcher import Request, RequestBatcher
from .prefix_cache import PrefixCache, PrefixCacheConfig, PrefixHit

# ---------------------------------------------------------------------------
# the per-request metrics fold (request slot == segment id)
# ---------------------------------------------------------------------------

# columns of the per-request metrics table — ONE additive fold carries all
# three: sum of sampled-token logprobs, count of generated tokens, and the
# stop condition as a summed indicator (eos_hits > 0 <=> OR of eos hits)
METRIC_COLS = ("logprob_sum", "tokens", "eos_hits")


def decode_metrics_init(num_slots: int) -> jnp.ndarray:
    """The identity table: (num_slots, len(METRIC_COLS)) float32 zeros."""
    return jnp.zeros((num_slots, len(METRIC_COLS)), jnp.float32)


def decode_metrics_plan(batch_rows: int, num_slots: int) -> Plan:
    """The plan of ONE decode step's per-request aggregation (no FLOPs).

    This is the contract the serving path is built on: B concurrent
    requests aggregate through a single keyed, masked fold — inspect the
    plan to see one local tier, not B of them.
    """
    return plan_fold(
        monoids.sum_,
        jax.ShapeDtypeStruct((batch_rows, len(METRIC_COLS)), jnp.float32),
        segment_ids=jax.ShapeDtypeStruct((batch_rows,), jnp.int32),
        num_segments=num_slots,
        valid_mask=jax.ShapeDtypeStruct((batch_rows,), jnp.bool_))


def metric_rows(logits: jnp.ndarray, sampled: jnp.ndarray,
                eos_id: int) -> jnp.ndarray:
    """(B, V) logits + (B,) sampled ids -> (B, 3) metric rows to fold."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_logp = jnp.take_along_axis(logp, sampled[:, None], axis=-1)[:, 0]
    return jnp.stack(
        [tok_logp, jnp.ones_like(tok_logp),
         (sampled == eos_id).astype(jnp.float32)], axis=-1)


def fold_decode_metrics(table: jnp.ndarray, rows: jnp.ndarray,
                        slot_ids: jnp.ndarray, active: jnp.ndarray,
                        num_slots: int) -> jnp.ndarray:
    """ONE planner-lowered keyed masked fold of metric rows into the table."""
    return execute_fold(monoids.sum_, rows, segment_ids=slot_ids,
                        num_segments=num_slots, valid_mask=active, init=table)


@functools.partial(jax.jit, static_argnames=("num_slots", "eos_id"))
def decode_metrics_step(table: jnp.ndarray, logits: jnp.ndarray,
                        sampled: jnp.ndarray, slot_ids: jnp.ndarray,
                        active: jnp.ndarray, *, num_slots: int,
                        eos_id: int) -> jnp.ndarray:
    """Fold one decode step's per-request aggregates into the running table.

    logits: (B, V) last-position logits; sampled: (B,) sampled token ids;
    slot_ids: (B,) request slot per row (segment ids); active: (B,) bool —
    rows still generating this step.  The whole batch reduces in ONE
    planner-lowered keyed fold; inactive/empty slots are masked to the
    identity, and the running table rides in as ``init`` (the fold across
    steps is the same monoid, re-bracketed — the paper's point).
    """
    rows = metric_rows(logits, sampled, eos_id)
    return fold_decode_metrics(table, rows, slot_ids, active, num_slots)


def extract_metrics(table: jnp.ndarray) -> Dict[str, np.ndarray]:
    """Read the metrics table out into per-slot host arrays."""
    t = np.asarray(table)
    return {
        "logprob_sum": t[:, 0],
        "tokens": t[:, 1].astype(np.int64),
        "stopped": t[:, 2] > 0,       # summed eos indicator == OR
    }


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One config object for the whole serving stack.

    Shared by :class:`ContinuousEngine`, ``repro.launch.serve`` (model
    wiring + CLI) and ``benchmarks/bench_serve.py`` — replaces the loose
    ``(arch, max_batch, max_seq, ...)`` keywords the PR-3 API threaded
    around.
    """

    arch: str = "qwen3-0.6b"
    num_slots: int = 4                       # rolling request slots (segment ids)
    prefill_buckets: Tuple[int, ...] = (16, 32)   # prompt-length ladder, ascending
    max_new_tokens: int = 16                 # per-request generation ceiling
    eos_id: int = 0
    pad_id: int = 0
    temperature: float = 0.0                 # 0 = greedy
    seed: int = 0                            # sampling PRNG seed
    model_parallel: int = 1
    full: bool = False                       # full-size config (default: smoke)
    # batched same-bucket admission: up to this many waiting requests with
    # the same suffix bucket prefill in ONE (k, bucket) program; the power-
    # of-two k-ladder keeps the compile bound declared
    prefill_batch: int = 1
    # radix prefix KV cache (runtime/prefix_cache.py): admissions look up
    # the longest cached block-aligned prefix, scatter its KV rows into the
    # slot cache, and prefill only the remaining suffix
    prefix_cache: bool = True
    prefix_block: int = 4                    # tokens per trie node
    prefix_capacity: int = 256               # trie nodes == stats-table rows
    prefix_max_bytes: Optional[int] = None   # resident-KV budget (None = off)
    prefix_half_life_s: float = 60.0         # decayed-LRU eviction half life

    def __post_init__(self):
        buckets = tuple(int(b) for b in self.prefill_buckets)
        if not buckets or any(b < 1 for b in buckets) or \
                list(buckets) != sorted(set(buckets)):
            raise ValueError(
                f"prefill_buckets must be distinct ascending positive ints, "
                f"got {self.prefill_buckets}")
        object.__setattr__(self, "prefill_buckets", buckets)
        if self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.prefill_batch < 1:
            raise ValueError("prefill_batch must be >= 1")
        if self.prefix_block < 1:
            raise ValueError("prefix_block must be >= 1")
        if self.prefix_capacity < 1:
            raise ValueError("prefix_capacity must be >= 1")

    @property
    def max_prompt(self) -> int:
        return self.prefill_buckets[-1]

    @property
    def prefill_k_ladder(self) -> Tuple[int, ...]:
        """Powers of two up to min(prefill_batch, num_slots) — the declared
        admission batch sizes (each is one compiled (k, bucket) program)."""
        ks, k = [], 1
        while k <= min(self.prefill_batch, self.num_slots):
            ks.append(k)
            k *= 2
        return tuple(ks)

    @property
    def prefix_slab(self) -> int:
        """Per-request prefix rows every prefill program accepts: the
        largest block multiple strictly below the biggest bucket (a hit
        must leave >= 1 suffix token to produce the first logits)."""
        return ((self.max_prompt - 1) // self.prefix_block) \
            * self.prefix_block

    @property
    def max_seq(self) -> int:
        """Cache length: the largest bucket plus the generation ceiling."""
        return self.prefill_buckets[-1] + self.max_new_tokens

    def bucket_for(self, prompt_len: int) -> int:
        """Smallest ladder bucket that fits the prompt."""
        for b in self.prefill_buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens exceeds the largest prefill "
            f"bucket ({self.prefill_buckets[-1]})")


# ---------------------------------------------------------------------------
# streaming API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestResult:
    """Final per-request record, built from the slot's metrics-table row."""

    uid: int
    slot: int
    prompt_len: int
    bucket: int
    user: int
    tokens: List[int]
    logprob_sum: float
    stopped: bool                 # hit eos (vs exhausted max_new_tokens)
    stop_step: int                # engine step count at retirement
    ttft_s: float                 # submit -> first streamed token
    latency_s: float              # submit -> retirement


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One streamed serving event.

    kind == "token": ``token``/``index`` are set; ``ttft_s`` on index 0.
    kind == "done":  ``result`` carries the full :class:`RequestResult`.
    kind == "cache": emitted at admission when the prefix cache is on —
      ``hit_tokens``/``prompt_tokens``/``bytes_saved`` feed the fleet
      prefix-hit-rate windows (``data.windows.WindowedMetrics``).
    """

    uid: int
    kind: str                     # "token" | "done" | "cache"
    slot: int
    step: int                     # engine step counter at emission
    time_s: float
    user: int = 0
    token: Optional[int] = None
    index: Optional[int] = None   # position in the generated sequence
    ttft_s: Optional[float] = None
    result: Optional[RequestResult] = None
    hit_tokens: Optional[int] = None      # prompt tokens served from cache
    prompt_tokens: Optional[int] = None   # total prompt tokens
    bytes_saved: Optional[int] = None     # KV bytes not re-prefilled


# ---------------------------------------------------------------------------
# backend contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineBackend:
    """What the engine needs from a model substrate.

    ``decode(params, cache, cur)`` must be *traceable* (the engine jits it,
    fused with sampling and the metrics fold) and row-independent: row b of
    the outputs depends only on row b of ``cache``/``cur``.  ``cur`` is
    ``(B, 1)`` int32; it returns ``((B, V) float32 logits, new cache)``.

    ``init_cache(batch, pos_per_slot)`` builds a fresh cache pytree whose
    leaves carry the batch dim at axis 0 (axis 1 under the ``stacked_key``
    subtree) plus a ``pos`` leaf — scalar, or ``(batch,)`` when
    ``pos_per_slot`` (the rolling cache).
    """

    decode: Callable[[Any, Any, jnp.ndarray], Tuple[jnp.ndarray, Any]]
    init_cache: Callable[[int, bool], Any]
    params: Any
    vocab_size: int
    stacked_key: str = "layers"   # cache subtree with a leading stack dim
    # True iff every non-``pos`` cache leaf is indexed by absolute sequence
    # position (see models.transformer.positional_cache) — the property
    # prefix KV sharing needs; recurrent-state substrates set this False
    # and the engine keeps cold prefills only
    prefix_sharing: bool = True
    # placement for the engine's initial device state (rolling cache +
    # metrics table).  Mesh-aware backends should commit with the SAME
    # sharding their jitted outputs carry — otherwise the first write_slot
    # call sees differently-placed args and compiles a second (identical)
    # executable for the same shape.
    place: Optional[Callable[[Any], Any]] = None


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    steps: int = 0                # decode steps over the rolling population
    slot_reuses: int = 0          # admissions into a previously-used slot
    generated_tokens: int = 0
    prefill_calls: int = 0        # prefill program invocations (k >= 1 each)
    batched_admissions: int = 0   # admissions that shared a k > 1 prefill


@dataclasses.dataclass
class _SlotState:
    uid: int
    user: int
    seed: int
    prompt_len: int
    bucket: int
    max_new: int
    arrival_s: float
    ttft_s: float
    tokens: List[int]
    cur: int                      # last sampled token (next step's input)

    @property
    def n_gen(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class _AdmitJob:
    """One admission in flight: request + slot + prefix-cache hit."""

    req: Request
    slot: int
    plen: int
    bucket: int                   # SUFFIX bucket (prompt minus cached prefix)
    seed: int
    hit: Optional[PrefixHit]
    first: int = 0                # first sampled token, set by _admit_chunk


class ContinuousEngine:
    """Admit and retire requests *mid-decode* over rolling request slots.

    Lifecycle per request: ``submit`` enqueues it on the FIFO admission
    queue (a :class:`~repro.runtime.batcher.RequestBatcher`); when slots
    free, ``_admit`` looks up each prompt's longest cached prefix in the
    radix trie, groups same-suffix-bucket requests into one compiled
    ``(k, bucket)`` prefill over a fresh k-row cache seeded with the cached
    prefix KV rows, scatters the result into the rolling cache (resetting
    each slot's cache position and metrics row), feeds the new KV blocks
    back into the trie, and streams each first token (TTFT).  Every
    ``step()`` then
    advances ALL occupied slots one token — model forward, per-row
    sampling, and ONE planner-lowered keyed masked fold of the per-request
    metrics — and retires slots that hit ``eos_id`` or their token budget,
    which immediately frees them for the next waiting request.
    """

    def __init__(self, backend: EngineBackend, config: ServeConfig, *,
                 clock: Callable[[], float] = time.perf_counter,
                 consumers: Sequence[Callable[[StreamEvent], None]] = ()):
        self.backend = backend
        self.config = config
        self._clock = clock
        # stream-event consumers (e.g. data.windows.WindowedMetrics.observe):
        # every event a step produces — admissions, tokens, retirements —
        # is dispatched to each consumer at the end of that step()
        self._consumers: List[Callable[[StreamEvent], None]] = list(consumers)
        # the batcher's FIFO is the admission queue: arrival order in,
        # arrival order into freed slots (take(), not flush()).
        self.queue = RequestBatcher(max_batch_size=config.num_slots,
                                    max_wait_s=0.0, clock=clock)
        self.stats = EngineStats()
        self.results: Dict[int, RequestResult] = {}
        self._slots: List[Optional[_SlotState]] = [None] * config.num_slots
        self._used_before = [False] * config.num_slots
        self._seeds: Dict[int, int] = {}
        self._step_count = 0
        place = backend.place if backend.place is not None else (lambda x: x)
        self._cache = place(backend.init_cache(config.num_slots, True))
        self._table = place(decode_metrics_init(config.num_slots))
        # -- radix prefix KV cache (runtime/prefix_cache.py) ----------------
        self.prefix: Optional[PrefixCache] = None
        self._slab = 0
        if config.prefix_cache and backend.prefix_sharing \
                and config.prefix_slab >= config.prefix_block:
            self.prefix = PrefixCache(
                PrefixCacheConfig(block=config.prefix_block,
                                  capacity=config.prefix_capacity,
                                  max_bytes=config.prefix_max_bytes,
                                  half_life_s=config.prefix_half_life_s),
                clock=clock)
            self._slab = config.prefix_slab
            # flattened view of the cache WITHOUT ``pos``: leaf order, batch
            # and sequence axes per leaf — the host-side (dis)assembly spec
            # for prefix slabs (the trie stores opaque per-leaf numpy blocks)
            tmpl = jax.eval_shape(lambda: backend.init_cache(1, True))
            kv_tmpl = {k: v for k, v in tmpl.items() if k != "pos"}
            leaves, treedef = jax.tree_util.tree_flatten_with_path(kv_tmpl)
            self._kv_treedef = treedef
            self._kv_shapes = [tuple(leaf.shape) for _, leaf in leaves]
            self._kv_dtypes = [leaf.dtype for _, leaf in leaves]
            self._kv_batch_axes = []
            for path, _ in leaves:
                keys = [getattr(e, "key", None) for e in path]
                self._kv_batch_axes.append(
                    1 if backend.stacked_key in keys else 0)
        self._build_compiled()

    # -- compiled programs (the whole shape ladder) -------------------------

    def _build_compiled(self) -> None:
        cfg = self.config
        S, V = cfg.num_slots, self.backend.vocab_size
        eos, temp = cfg.eos_id, cfg.temperature
        decode = self.backend.decode
        stacked = self.backend.stacked_key
        base_seed = cfg.seed

        def sample_rows(logits, seeds, tok_idx):
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            base = jax.random.PRNGKey(base_seed)

            def one(s, i, l):
                k = jax.random.fold_in(jax.random.fold_in(base, s), i)
                return jax.random.categorical(k, l / temp)

            # per-request key streams (seed, token index): sampling is
            # independent of slot assignment and neighbours, so a request
            # decodes identically alone or in a rolling batch
            return jax.vmap(one)(seeds, tok_idx, logits).astype(jnp.int32)

        def step_impl(params, cache, cur, active, seeds, tok_idx, table):
            logits, cache = decode(params, cache, cur)
            sampled = sample_rows(logits, seeds, tok_idx)
            rows = metric_rows(logits, sampled, eos)
            table = fold_decode_metrics(
                table, rows, jnp.arange(S, dtype=jnp.int32), active, S)
            return cache, sampled, table

        self._step_fn = jax.jit(step_impl, donate_argnums=(1,))

        slab = self._slab
        kv_treedef = getattr(self, "_kv_treedef", None)

        def load_prefix(cachek, prefix_leaves, prefix_len):
            """Scatter cached prefix KV rows into a fresh k-row prefill
            cache (rows beyond each request's prefix are zeros over zeros)
            and start each row's position at its prefix length."""
            kv = {key: val for key, val in cachek.items() if key != "pos"}
            slabs = jax.tree_util.tree_unflatten(kv_treedef, prefix_leaves)

            def put(path, big, small):
                keys = [getattr(e, "key", None) for e in path]
                axis = 2 if stacked in keys else 1
                return cache_span_update(big, small.astype(big.dtype),
                                         jnp.int32(0), seq_axis=axis)

            kv = jax.tree_util.tree_map_with_path(put, kv, slabs)
            kv["pos"] = jnp.asarray(prefix_len, cachek["pos"].dtype)
            return kv

        def make_prefill(k: int, bucket: int):
            def scan_suffix(params, cachek, toks, lengths, seeds):
                def body(carry, x):
                    cache, last = carry
                    tok, i = x
                    logits, cache = decode(params, cache, tok[:, None])
                    last = jnp.where((i == lengths - 1)[:, None], logits,
                                     last)
                    return (cache, last), None

                xs = (toks.T, jnp.arange(bucket))
                (cachek, last), _ = jax.lax.scan(
                    body, (cachek, jnp.zeros((k, V), jnp.float32)), xs)
                sampled = sample_rows(last, seeds,
                                      jnp.zeros((k,), jnp.int32))
                return cachek, sampled, metric_rows(last, sampled, eos)

            if slab:
                def prefill_impl(params, cachek, toks, lengths, seeds,
                                 prefix_leaves, prefix_len):
                    cachek = load_prefix(cachek, prefix_leaves, prefix_len)
                    return scan_suffix(params, cachek, toks, lengths, seeds)
            else:
                def prefill_impl(params, cachek, toks, lengths, seeds):
                    return scan_suffix(params, cachek, toks, lengths, seeds)

            return jax.jit(prefill_impl, donate_argnums=(1,))

        self._prefill_fns = {(k, b): make_prefill(k, b)
                             for k in cfg.prefill_k_ladder
                             for b in cfg.prefill_buckets}

        def make_write(k: int):
            def write_impl(cache, cachek, slots, lengths, table, rows):
                def put(path, big, small):
                    keys = [getattr(e, "key", None) for e in path]
                    if keys and keys[0] == "pos":
                        # each slot restarts at its full prompt length
                        # (positions are per-slot)
                        return big.at[slots].set(lengths.astype(big.dtype))
                    axis = 1 if stacked in keys else 0
                    out = big
                    for r in range(k):
                        piece = jax.lax.dynamic_slice_in_dim(
                            small, r, 1, axis=axis)
                        out = jax.lax.dynamic_update_slice_in_dim(
                            out, piece.astype(out.dtype), slots[r],
                            axis=axis)
                    return out

                new = jax.tree_util.tree_map_with_path(put, cache, cachek)
                # reset + first tokens in one write: each row IS its slot's
                # first metrics fold
                return new, table.at[slots].set(rows)

            return jax.jit(write_impl, donate_argnums=(0, 1, 4))

        self._write_fns = {k: make_write(k) for k in cfg.prefill_k_ladder}

        def make_gather(k: int):
            def gather_impl(cachek):
                kv = {key: val for key, val in cachek.items()
                      if key != "pos"}

                def take(path, leaf):
                    keys = [getattr(e, "key", None) for e in path]
                    axis = 2 if stacked in keys else 1
                    return jax.lax.slice_in_dim(leaf, 0, slab, axis=axis)

                return jax.tree_util.tree_map_with_path(take, kv)

            return jax.jit(gather_impl)

        self._gather_fns = {} if not slab else \
            {k: make_gather(k) for k in cfg.prefill_k_ladder}

    def compile_counts(self) -> Dict[str, int]:
        """Distinct compiled shapes per engine program.  The declared bound
        (:meth:`compile_bound`): one step program, one write + one prefix
        gather per admission batch size k, one prefill per (k, bucket), and
        the prefix cache's stats fold + row reset."""
        def n(f):
            try:
                return int(f._cache_size())
            except Exception:      # pragma: no cover - older jax
                return -1

        counts = {"step": n(self._step_fn)}
        for (k, b), f in self._prefill_fns.items():
            counts[f"prefill_k{k}_b{b}"] = n(f)
        for k, f in self._write_fns.items():
            counts[f"write_k{k}"] = n(f)
        for k, f in self._gather_fns.items():
            counts[f"gather_k{k}"] = n(f)
        if self.prefix is not None:
            counts.update(self.prefix.compile_counts())
        return counts

    def compile_bound(self) -> int:
        """The declared ceiling on distinct compiled shapes over ANY trace:
        ``1 step + |k| x |buckets| prefills + |k| writes`` plus, with the
        prefix cache on, ``|k| gathers + stats fold + row reset``."""
        cfg = self.config
        kk = len(cfg.prefill_k_ladder)
        n = 1 + kk * len(cfg.prefill_buckets) + kk
        if self.prefix is not None:
            n += kk + 2
        return n

    # -- request lifecycle --------------------------------------------------

    @property
    def pending(self) -> int:
        """Requests waiting in the admission queue."""
        return len(self.queue)

    @property
    def num_active(self) -> int:
        """Slots currently occupied by a generating request."""
        return sum(s is not None for s in self._slots)

    @property
    def active_uids(self) -> List[int]:
        return [s.uid for s in self._slots if s is not None]

    def subscribe(self, consumer: Callable[[StreamEvent], None]) -> None:
        """Add a stream-event consumer (called once per event, in event
        order, at the end of each :meth:`step`)."""
        self._consumers.append(consumer)

    def submit(self, prompt: Sequence[int], *,
               max_new_tokens: Optional[int] = None,
               seed: Optional[int] = None, user: int = 0) -> int:
        """Enqueue a request; returns its uid.  Admission happens on the
        next :meth:`step` as soon as a slot is free."""
        cfg = self.config
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("prompt must be non-empty")
        cfg.bucket_for(len(prompt))      # raises if it exceeds the ladder
        max_new = cfg.max_new_tokens if max_new_tokens is None \
            else int(max_new_tokens)
        if not (1 <= max_new <= cfg.max_new_tokens):
            raise ValueError(
                f"max_new_tokens must be in [1, {cfg.max_new_tokens}], "
                f"got {max_new}")
        uid = self.queue.submit(prompt, max_new_tokens=max_new,
                                user=int(user))
        self._seeds[uid] = uid if seed is None else int(seed)
        self.stats.submitted += 1
        return uid

    def result(self, uid: int) -> RequestResult:
        return self.results[uid]

    def _admit(self, events: List[StreamEvent]) -> None:
        free = [i for i, s in enumerate(self._slots) if s is None]
        if not free:
            return
        reqs = self.queue.take(len(free))
        if not reqs:
            return
        cfg = self.config
        jobs: List[_AdmitJob] = []
        for req, slot in zip(reqs, free):
            plen = len(req.prompt)
            # the trie walk: requests prefill only their uncached suffix,
            # and the prefill bucket is chosen on SUFFIX length
            hit = self.prefix.lookup(req.prompt) \
                if self.prefix is not None else None
            hit_len = hit.length if hit is not None else 0
            jobs.append(_AdmitJob(
                req=req, slot=slot, plen=plen, hit=hit,
                seed=self._seeds.pop(req.uid, req.uid),
                bucket=cfg.bucket_for(plen - hit_len)))

        # group same-bucket admissions into shared (k, bucket) prefill
        # programs, k drawn from the declared power-of-two ladder
        groups: Dict[int, List[_AdmitJob]] = {}
        order: List[int] = []
        for job in jobs:
            if job.bucket not in groups:
                groups[job.bucket] = []
                order.append(job.bucket)
            groups[job.bucket].append(job)
        ladder = cfg.prefill_k_ladder
        for b in order:
            group = groups[b]
            while group:
                k = max(x for x in ladder if x <= len(group))
                self._admit_chunk(group[:k], b)
                group = group[k:]

        # stream in arrival order regardless of chunk grouping: the
        # admission accounting ("cache") event, then the first token
        now = self._clock()
        retire: List[int] = []
        for job in jobs:
            st = _SlotState(uid=job.req.uid, user=job.req.user,
                            seed=job.seed, prompt_len=job.plen,
                            bucket=job.bucket,
                            max_new=job.req.max_new_tokens,
                            arrival_s=job.req.arrival_s,
                            ttft_s=now - job.req.arrival_s,
                            tokens=[job.first], cur=job.first)
            self._slots[job.slot] = st
            self.stats.admitted += 1
            self.stats.generated_tokens += 1
            if self._used_before[job.slot]:
                self.stats.slot_reuses += 1
            self._used_before[job.slot] = True
            if job.hit is not None:
                events.append(StreamEvent(
                    uid=st.uid, kind="cache", slot=job.slot,
                    step=self._step_count, time_s=now, user=st.user,
                    hit_tokens=job.hit.length, prompt_tokens=job.plen,
                    bytes_saved=job.hit.nbytes))
            events.append(StreamEvent(uid=st.uid, kind="token",
                                      slot=job.slot, step=self._step_count,
                                      time_s=now, user=st.user,
                                      token=job.first, index=0,
                                      ttft_s=st.ttft_s))
            if job.first == cfg.eos_id or st.max_new <= 1:
                retire.append(job.slot)
        if retire:
            self._retire(retire, events, now)

    def _admit_chunk(self, jobs: List[_AdmitJob], bucket: int) -> None:
        """Prefill up to k same-bucket requests in ONE compiled program,
        scatter their (prefix-loaded) caches into the rolling cache, and
        feed each request's first-slab KV back into the trie."""
        cfg = self.config
        k = len(jobs)
        toks = np.full((k, bucket), cfg.pad_id, np.int32)
        suffix_lens = np.zeros((k,), np.int32)
        plens = np.zeros((k,), np.int32)
        seeds = np.zeros((k,), np.int32)
        prefix_lens = np.zeros((k,), np.int32)
        for r, job in enumerate(jobs):
            hit_len = job.hit.length if job.hit is not None else 0
            suffix = job.req.prompt[hit_len:]
            toks[r, :len(suffix)] = suffix
            suffix_lens[r] = len(suffix)
            plens[r] = job.plen
            seeds[r] = job.seed
            prefix_lens[r] = hit_len
        cachek = self.backend.init_cache(k, True)
        fn = self._prefill_fns[(k, bucket)]
        if self._slab:
            leaves = [jnp.asarray(a) for a in self._assemble_prefix(jobs, k)]
            cachek, sampled, rows = fn(
                self.backend.params, cachek, jnp.asarray(toks),
                jnp.asarray(suffix_lens), jnp.asarray(seeds), leaves,
                jnp.asarray(prefix_lens))
        else:
            cachek, sampled, rows = fn(
                self.backend.params, cachek, jnp.asarray(toks),
                jnp.asarray(suffix_lens), jnp.asarray(seeds))
        # gather BEFORE the (donating) slot write: the first `slab` KV rows
        # of every admitted request, host-side, become trie payloads
        gathered = None
        if self.prefix is not None:
            gathered = jax.device_get(self._gather_fns[k](cachek))
        slots = np.asarray([j.slot for j in jobs], np.int32)
        self._cache, self._table = self._write_fns[k](
            self._cache, cachek, jnp.asarray(slots), jnp.asarray(plens),
            self._table, rows)
        sampled_np = np.asarray(jax.device_get(sampled))
        for r, job in enumerate(jobs):
            job.first = int(sampled_np[r])
        if gathered is not None:
            g_leaves = jax.tree_util.tree_leaves(gathered)
            max_blocks = self._slab // cfg.prefix_block
            for r, job in enumerate(jobs):
                self.prefix.insert(
                    job.req.prompt,
                    lambda i, r=r: self._slice_block(g_leaves, r, i),
                    max_blocks=max_blocks)
        self.stats.prefill_calls += 1
        if k > 1:
            self.stats.batched_admissions += k

    def _assemble_prefix(self, jobs: List[_AdmitJob],
                         k: int) -> List[np.ndarray]:
        """Pack each job's cached prefix blocks into fixed (k, slab) KV
        slabs (one per cache leaf; rows past a job's prefix stay zero)."""
        B = self.config.prefix_block
        out = []
        for shape, dtype, bax in zip(self._kv_shapes, self._kv_dtypes,
                                     self._kv_batch_axes):
            s = list(shape)
            s[bax] = k
            s[bax + 1] = self._slab
            out.append(np.zeros(s, dtype))
        for r, job in enumerate(jobs):
            if job.hit is None:
                continue
            for i, blk in enumerate(job.hit.blocks):
                for j, arr in enumerate(blk):
                    bax = self._kv_batch_axes[j]
                    idx = [slice(None)] * out[j].ndim
                    idx[bax] = slice(r, r + 1)
                    idx[bax + 1] = slice(i * B, (i + 1) * B)
                    out[j][tuple(idx)] = arr
        return out

    def _slice_block(self, leaves: List[np.ndarray], r: int,
                     i: int) -> List[np.ndarray]:
        """Trie payload for request row r, block i: one `block`-row slice
        per gathered cache leaf (batch dim kept at size 1)."""
        B = self.config.prefix_block
        out = []
        for j, arr in enumerate(leaves):
            bax = self._kv_batch_axes[j]
            idx = [slice(None)] * arr.ndim
            idx[bax] = slice(r, r + 1)
            idx[bax + 1] = slice(i * B, (i + 1) * B)
            out.append(np.ascontiguousarray(arr[tuple(idx)]))
        return out

    def _retire(self, slots: List[int], events: List[StreamEvent],
                now: float) -> None:
        table = np.asarray(jax.device_get(self._table))
        for slot in slots:
            st = self._slots[slot]
            res = RequestResult(
                uid=st.uid, slot=slot, prompt_len=st.prompt_len,
                bucket=st.bucket, user=st.user, tokens=list(st.tokens),
                logprob_sum=float(table[slot, 0]),
                stopped=bool(table[slot, 2] > 0),
                stop_step=self._step_count,
                ttft_s=st.ttft_s, latency_s=now - st.arrival_s)
            self.results[st.uid] = res
            self._slots[slot] = None
            self.stats.completed += 1
            events.append(StreamEvent(uid=st.uid, kind="done", slot=slot,
                                      step=self._step_count, time_s=now,
                                      user=st.user, result=res))

    # -- the rolling decode step --------------------------------------------

    def step(self) -> List[StreamEvent]:
        """Admit waiting requests into free slots, then advance the whole
        rolling population one token.  Returns the streamed events."""
        events: List[StreamEvent] = []
        self._admit(events)
        if self.prefix is not None:
            # ONE keyed stats fold per engine step carries every cache
            # event this step produced (hits + inserts)
            self.prefix.flush_stats()
        S = self.config.num_slots
        occupied = [i for i, s in enumerate(self._slots) if s is not None]
        if not occupied:
            return self._dispatch(events)

        cur = np.zeros((S, 1), np.int32)
        active = np.zeros((S,), bool)
        seeds = np.zeros((S,), np.int32)
        tok_idx = np.zeros((S,), np.int32)
        for i in occupied:
            st = self._slots[i]
            cur[i, 0] = st.cur
            active[i] = True
            seeds[i] = st.seed
            tok_idx[i] = st.n_gen
        self._cache, sampled, self._table = self._step_fn(
            self.backend.params, self._cache, jnp.asarray(cur),
            jnp.asarray(active), jnp.asarray(seeds), jnp.asarray(tok_idx),
            self._table)
        self._step_count += 1
        self.stats.steps += 1

        sampled_np = np.asarray(jax.device_get(sampled))
        now = self._clock()
        retired = []
        for i in occupied:
            st = self._slots[i]
            tok = int(sampled_np[i])
            index = st.n_gen
            st.tokens.append(tok)
            st.cur = tok
            self.stats.generated_tokens += 1
            events.append(StreamEvent(uid=st.uid, kind="token", slot=i,
                                      step=self._step_count, time_s=now,
                                      user=st.user, token=tok, index=index))
            if tok == self.config.eos_id or st.n_gen >= st.max_new:
                retired.append(i)
        if retired:
            self._retire(retired, events, now)
        return self._dispatch(events)

    def _dispatch(self, events: List[StreamEvent]) -> List[StreamEvent]:
        for ev in events:
            for consumer in self._consumers:
                consumer(ev)
        return events

    def run(self, *, max_steps: Optional[int] = None) -> Iterator[StreamEvent]:
        """Stream events until the queue and every slot drain."""
        steps = 0
        while self.pending or self.num_active:
            yield from self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(
                    f"engine did not drain within {max_steps} steps "
                    f"({self.pending} pending, {self.num_active} active)")
