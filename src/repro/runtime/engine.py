"""Continuous-batching serve engine: rolling request slots, bucketed
compilation, streaming decode.

The paper's point is that monoid aggregation states can be merged
incrementally, anywhere, at any time — which is precisely what a
continuously-batched decode loop needs.  A fixed batch decoded to
completion (PR 3's ``run_batched_decode``) wastes every slot whose request
finished early; here a freed slot (= segment id, the planner's keyed-fold
key) is handed to the next waiting request *mid-decode*, and the
per-request metrics keep folding through the SAME keyed masked fold
(:func:`decode_metrics_step`) over the rolling slot population — the fold
never needs to know a slot changed hands, because the running table is just
a monoid value re-bracketed across admissions (``init=`` carries it).

Compilation is bucketed so slot churn never recompiles anything:

* ONE decode-step program at ``(num_slots, 1)`` — model forward + per-row
  sampling + the keyed masked metrics fold, jitted together.
* ONE prefill program per ``prefill_bucket`` in the ladder — a
  ``lax.scan`` of the decode step over a prompt padded to the bucket,
  against a fresh single-slot cache.
* ONE slot-write program — scatter the prefilled single-slot cache into
  the rolling cache at the freed slot (and reset that slot's metrics row).

So the number of distinct jitted shapes is bounded by
``len(prefill_buckets) + 2`` for the whole engine lifetime (the
recompile-count test in tests/test_serving.py asserts this).  Padding to
the nearest bucket trades bounded extra prefill FLOPs for zero recompiles —
the external-memory cost-model trade (Greiner & Jacob, PAPERS.md): pay
predictable padding, never pay compilation.

Slot independence is guaranteed by the model layer's per-slot cache
positions (``init_cache(pos_per_slot=True)``): each row writes and masks
its KV at its own position, so a reused slot's computation is bit-identical
to the same request decoded alone.

The engine is model-agnostic: it drives an :class:`EngineBackend` (a
traceable decode function + cache constructor), so the whole slot/admission
machinery is testable without a model.  ``repro.launch.serve.build_engine``
wires the real model substrate; the stable import surface is
``repro.serving``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import monoids
from ..core.plan import Plan, execute_fold, plan_fold
from .batcher import Request, RequestBatcher

# ---------------------------------------------------------------------------
# the per-request metrics fold (request slot == segment id)
# ---------------------------------------------------------------------------

# columns of the per-request metrics table — ONE additive fold carries all
# three: sum of sampled-token logprobs, count of generated tokens, and the
# stop condition as a summed indicator (eos_hits > 0 <=> OR of eos hits)
METRIC_COLS = ("logprob_sum", "tokens", "eos_hits")


def decode_metrics_init(num_slots: int) -> jnp.ndarray:
    """The identity table: (num_slots, len(METRIC_COLS)) float32 zeros."""
    return jnp.zeros((num_slots, len(METRIC_COLS)), jnp.float32)


def decode_metrics_plan(batch_rows: int, num_slots: int) -> Plan:
    """The plan of ONE decode step's per-request aggregation (no FLOPs).

    This is the contract the serving path is built on: B concurrent
    requests aggregate through a single keyed, masked fold — inspect the
    plan to see one local tier, not B of them.
    """
    return plan_fold(
        monoids.sum_,
        jax.ShapeDtypeStruct((batch_rows, len(METRIC_COLS)), jnp.float32),
        segment_ids=jax.ShapeDtypeStruct((batch_rows,), jnp.int32),
        num_segments=num_slots,
        valid_mask=jax.ShapeDtypeStruct((batch_rows,), jnp.bool_))


def metric_rows(logits: jnp.ndarray, sampled: jnp.ndarray,
                eos_id: int) -> jnp.ndarray:
    """(B, V) logits + (B,) sampled ids -> (B, 3) metric rows to fold."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_logp = jnp.take_along_axis(logp, sampled[:, None], axis=-1)[:, 0]
    return jnp.stack(
        [tok_logp, jnp.ones_like(tok_logp),
         (sampled == eos_id).astype(jnp.float32)], axis=-1)


def fold_decode_metrics(table: jnp.ndarray, rows: jnp.ndarray,
                        slot_ids: jnp.ndarray, active: jnp.ndarray,
                        num_slots: int) -> jnp.ndarray:
    """ONE planner-lowered keyed masked fold of metric rows into the table."""
    return execute_fold(monoids.sum_, rows, segment_ids=slot_ids,
                        num_segments=num_slots, valid_mask=active, init=table)


@functools.partial(jax.jit, static_argnames=("num_slots", "eos_id"))
def decode_metrics_step(table: jnp.ndarray, logits: jnp.ndarray,
                        sampled: jnp.ndarray, slot_ids: jnp.ndarray,
                        active: jnp.ndarray, *, num_slots: int,
                        eos_id: int) -> jnp.ndarray:
    """Fold one decode step's per-request aggregates into the running table.

    logits: (B, V) last-position logits; sampled: (B,) sampled token ids;
    slot_ids: (B,) request slot per row (segment ids); active: (B,) bool —
    rows still generating this step.  The whole batch reduces in ONE
    planner-lowered keyed fold; inactive/empty slots are masked to the
    identity, and the running table rides in as ``init`` (the fold across
    steps is the same monoid, re-bracketed — the paper's point).
    """
    rows = metric_rows(logits, sampled, eos_id)
    return fold_decode_metrics(table, rows, slot_ids, active, num_slots)


def extract_metrics(table: jnp.ndarray) -> Dict[str, np.ndarray]:
    """Read the metrics table out into per-slot host arrays."""
    t = np.asarray(table)
    return {
        "logprob_sum": t[:, 0],
        "tokens": t[:, 1].astype(np.int64),
        "stopped": t[:, 2] > 0,       # summed eos indicator == OR
    }


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One config object for the whole serving stack.

    Shared by :class:`ContinuousEngine`, ``repro.launch.serve`` (model
    wiring + CLI) and ``benchmarks/bench_serve.py`` — replaces the loose
    ``(arch, max_batch, max_seq, ...)`` keywords the PR-3 API threaded
    around.
    """

    arch: str = "qwen3-0.6b"
    num_slots: int = 4                       # rolling request slots (segment ids)
    prefill_buckets: Tuple[int, ...] = (16, 32)   # prompt-length ladder, ascending
    max_new_tokens: int = 16                 # per-request generation ceiling
    eos_id: int = 0
    pad_id: int = 0
    temperature: float = 0.0                 # 0 = greedy
    seed: int = 0                            # sampling PRNG seed
    model_parallel: int = 1
    full: bool = False                       # full-size config (default: smoke)

    def __post_init__(self):
        buckets = tuple(int(b) for b in self.prefill_buckets)
        if not buckets or any(b < 1 for b in buckets) or \
                list(buckets) != sorted(set(buckets)):
            raise ValueError(
                f"prefill_buckets must be distinct ascending positive ints, "
                f"got {self.prefill_buckets}")
        object.__setattr__(self, "prefill_buckets", buckets)
        if self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")

    @property
    def max_prompt(self) -> int:
        return self.prefill_buckets[-1]

    @property
    def max_seq(self) -> int:
        """Cache length: the largest bucket plus the generation ceiling."""
        return self.prefill_buckets[-1] + self.max_new_tokens

    def bucket_for(self, prompt_len: int) -> int:
        """Smallest ladder bucket that fits the prompt."""
        for b in self.prefill_buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens exceeds the largest prefill "
            f"bucket ({self.prefill_buckets[-1]})")


# ---------------------------------------------------------------------------
# streaming API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestResult:
    """Final per-request record, built from the slot's metrics-table row."""

    uid: int
    slot: int
    prompt_len: int
    bucket: int
    user: int
    tokens: List[int]
    logprob_sum: float
    stopped: bool                 # hit eos (vs exhausted max_new_tokens)
    stop_step: int                # engine step count at retirement
    ttft_s: float                 # submit -> first streamed token
    latency_s: float              # submit -> retirement


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One streamed serving event.

    kind == "token": ``token``/``index`` are set; ``ttft_s`` on index 0.
    kind == "done":  ``result`` carries the full :class:`RequestResult`.
    """

    uid: int
    kind: str                     # "token" | "done"
    slot: int
    step: int                     # engine step counter at emission
    time_s: float
    user: int = 0
    token: Optional[int] = None
    index: Optional[int] = None   # position in the generated sequence
    ttft_s: Optional[float] = None
    result: Optional[RequestResult] = None


# ---------------------------------------------------------------------------
# backend contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineBackend:
    """What the engine needs from a model substrate.

    ``decode(params, cache, cur)`` must be *traceable* (the engine jits it,
    fused with sampling and the metrics fold) and row-independent: row b of
    the outputs depends only on row b of ``cache``/``cur``.  ``cur`` is
    ``(B, 1)`` int32; it returns ``((B, V) float32 logits, new cache)``.

    ``init_cache(batch, pos_per_slot)`` builds a fresh cache pytree whose
    leaves carry the batch dim at axis 0 (axis 1 under the ``stacked_key``
    subtree) plus a ``pos`` leaf — scalar, or ``(batch,)`` when
    ``pos_per_slot`` (the rolling cache).
    """

    decode: Callable[[Any, Any, jnp.ndarray], Tuple[jnp.ndarray, Any]]
    init_cache: Callable[[int, bool], Any]
    params: Any
    vocab_size: int
    stacked_key: str = "layers"   # cache subtree with a leading stack dim
    # placement for the engine's initial device state (rolling cache +
    # metrics table).  Mesh-aware backends should commit with the SAME
    # sharding their jitted outputs carry — otherwise the first write_slot
    # call sees differently-placed args and compiles a second (identical)
    # executable for the same shape.
    place: Optional[Callable[[Any], Any]] = None


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    steps: int = 0                # decode steps over the rolling population
    slot_reuses: int = 0          # admissions into a previously-used slot
    generated_tokens: int = 0


@dataclasses.dataclass
class _SlotState:
    uid: int
    user: int
    seed: int
    prompt_len: int
    bucket: int
    max_new: int
    arrival_s: float
    ttft_s: float
    tokens: List[int]
    cur: int                      # last sampled token (next step's input)

    @property
    def n_gen(self) -> int:
        return len(self.tokens)


class ContinuousEngine:
    """Admit and retire requests *mid-decode* over rolling request slots.

    Lifecycle per request: ``submit`` enqueues it on the FIFO admission
    queue (a :class:`~repro.runtime.batcher.RequestBatcher`); when a slot
    frees, ``_admit`` pads the prompt to the nearest prefill bucket, runs
    the bucket's compiled prefill into a single-slot cache, scatters it
    into the rolling cache (resetting the slot's cache position and metrics
    row), and streams the first token (TTFT).  Every ``step()`` then
    advances ALL occupied slots one token — model forward, per-row
    sampling, and ONE planner-lowered keyed masked fold of the per-request
    metrics — and retires slots that hit ``eos_id`` or their token budget,
    which immediately frees them for the next waiting request.
    """

    def __init__(self, backend: EngineBackend, config: ServeConfig, *,
                 clock: Callable[[], float] = time.perf_counter,
                 consumers: Sequence[Callable[[StreamEvent], None]] = ()):
        self.backend = backend
        self.config = config
        self._clock = clock
        # stream-event consumers (e.g. data.windows.WindowedMetrics.observe):
        # every event a step produces — admissions, tokens, retirements —
        # is dispatched to each consumer at the end of that step()
        self._consumers: List[Callable[[StreamEvent], None]] = list(consumers)
        # the batcher's FIFO is the admission queue: arrival order in,
        # arrival order into freed slots (take(), not flush()).
        self.queue = RequestBatcher(max_batch_size=config.num_slots,
                                    max_wait_s=0.0, clock=clock)
        self.stats = EngineStats()
        self.results: Dict[int, RequestResult] = {}
        self._slots: List[Optional[_SlotState]] = [None] * config.num_slots
        self._used_before = [False] * config.num_slots
        self._seeds: Dict[int, int] = {}
        self._step_count = 0
        place = backend.place if backend.place is not None else (lambda x: x)
        self._cache = place(backend.init_cache(config.num_slots, True))
        self._table = place(decode_metrics_init(config.num_slots))
        self._build_compiled()

    # -- compiled programs (the whole shape ladder) -------------------------

    def _build_compiled(self) -> None:
        cfg = self.config
        S, V = cfg.num_slots, self.backend.vocab_size
        eos, temp = cfg.eos_id, cfg.temperature
        decode = self.backend.decode
        stacked = self.backend.stacked_key
        base_seed = cfg.seed

        def sample_rows(logits, seeds, tok_idx):
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            base = jax.random.PRNGKey(base_seed)

            def one(s, i, l):
                k = jax.random.fold_in(jax.random.fold_in(base, s), i)
                return jax.random.categorical(k, l / temp)

            # per-request key streams (seed, token index): sampling is
            # independent of slot assignment and neighbours, so a request
            # decodes identically alone or in a rolling batch
            return jax.vmap(one)(seeds, tok_idx, logits).astype(jnp.int32)

        def step_impl(params, cache, cur, active, seeds, tok_idx, table):
            logits, cache = decode(params, cache, cur)
            sampled = sample_rows(logits, seeds, tok_idx)
            rows = metric_rows(logits, sampled, eos)
            table = fold_decode_metrics(
                table, rows, jnp.arange(S, dtype=jnp.int32), active, S)
            return cache, sampled, table

        self._step_fn = jax.jit(step_impl, donate_argnums=(1,))

        def make_prefill(bucket: int):
            def prefill_impl(params, cache1, toks, length, seed):
                def body(carry, x):
                    cache, last = carry
                    tok, i = x
                    logits, cache = decode(params, cache, tok[:, None])
                    last = jnp.where(i == length - 1, logits, last)
                    return (cache, last), None

                xs = (toks.T, jnp.arange(bucket))
                (cache1, last), _ = jax.lax.scan(
                    body, (cache1, jnp.zeros((1, V), jnp.float32)), xs)
                sampled = sample_rows(last, jnp.full((1,), seed, jnp.int32),
                                      jnp.zeros((1,), jnp.int32))
                row = metric_rows(last, sampled, eos)[0]
                return cache1, sampled[0], row

            return jax.jit(prefill_impl, donate_argnums=(1,))

        self._prefill_fns = {b: make_prefill(b) for b in cfg.prefill_buckets}

        def write_impl(cache, cache1, slot, length, table, row):
            def put(path, big, small):
                keys = [getattr(e, "key", None) for e in path]
                if keys and keys[0] == "pos":
                    # slot restarts at its prompt length (positions are
                    # per-slot: init_cache(pos_per_slot=True))
                    return big.at[slot].set(jnp.asarray(length, big.dtype))
                axis = 1 if stacked in keys else 0
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small, slot, axis=axis)

            new = jax.tree_util.tree_map_with_path(put, cache, cache1)
            # reset + first token in one write: the row IS the first fold
            return new, table.at[slot].set(row)

        self._write_fn = jax.jit(write_impl, donate_argnums=(0, 1, 4))

    def compile_counts(self) -> Dict[str, int]:
        """Distinct compiled shapes per engine program (the bucket-ladder
        bound: step == 1, write_slot == 1, each prefill bucket <= 1)."""
        def n(f):
            try:
                return int(f._cache_size())
            except Exception:      # pragma: no cover - older jax
                return -1

        counts = {"step": n(self._step_fn), "write_slot": n(self._write_fn)}
        for b, f in self._prefill_fns.items():
            counts[f"prefill_{b}"] = n(f)
        return counts

    # -- request lifecycle --------------------------------------------------

    @property
    def pending(self) -> int:
        """Requests waiting in the admission queue."""
        return len(self.queue)

    @property
    def num_active(self) -> int:
        """Slots currently occupied by a generating request."""
        return sum(s is not None for s in self._slots)

    @property
    def active_uids(self) -> List[int]:
        return [s.uid for s in self._slots if s is not None]

    def subscribe(self, consumer: Callable[[StreamEvent], None]) -> None:
        """Add a stream-event consumer (called once per event, in event
        order, at the end of each :meth:`step`)."""
        self._consumers.append(consumer)

    def submit(self, prompt: Sequence[int], *,
               max_new_tokens: Optional[int] = None,
               seed: Optional[int] = None, user: int = 0) -> int:
        """Enqueue a request; returns its uid.  Admission happens on the
        next :meth:`step` as soon as a slot is free."""
        cfg = self.config
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("prompt must be non-empty")
        cfg.bucket_for(len(prompt))      # raises if it exceeds the ladder
        max_new = cfg.max_new_tokens if max_new_tokens is None \
            else int(max_new_tokens)
        if not (1 <= max_new <= cfg.max_new_tokens):
            raise ValueError(
                f"max_new_tokens must be in [1, {cfg.max_new_tokens}], "
                f"got {max_new}")
        uid = self.queue.submit(prompt, max_new_tokens=max_new,
                                user=int(user))
        self._seeds[uid] = uid if seed is None else int(seed)
        self.stats.submitted += 1
        return uid

    def result(self, uid: int) -> RequestResult:
        return self.results[uid]

    def _admit(self, events: List[StreamEvent]) -> None:
        free = [i for i, s in enumerate(self._slots) if s is None]
        if not free:
            return
        for req, slot in zip(self.queue.take(len(free)), free):
            self._admit_one(req, slot, events)

    def _admit_one(self, req: Request, slot: int,
                   events: List[StreamEvent]) -> None:
        cfg = self.config
        plen = len(req.prompt)
        bucket = cfg.bucket_for(plen)
        toks = np.full((1, bucket), cfg.pad_id, np.int32)
        toks[0, :plen] = req.prompt
        seed = self._seeds.pop(req.uid, req.uid)
        cache1 = self.backend.init_cache(1, False)
        cache1, first, row = self._prefill_fns[bucket](
            self.backend.params, cache1, jnp.asarray(toks), plen, seed)
        self._cache, self._table = self._write_fn(
            self._cache, cache1, slot, plen, self._table, row)
        first = int(jax.device_get(first))
        now = self._clock()
        ttft = now - req.arrival_s
        st = _SlotState(uid=req.uid, user=req.user, seed=seed,
                        prompt_len=plen, bucket=bucket,
                        max_new=req.max_new_tokens,
                        arrival_s=req.arrival_s, ttft_s=ttft,
                        tokens=[first], cur=first)
        self._slots[slot] = st
        self.stats.admitted += 1
        self.stats.generated_tokens += 1
        if self._used_before[slot]:
            self.stats.slot_reuses += 1
        self._used_before[slot] = True
        events.append(StreamEvent(uid=st.uid, kind="token", slot=slot,
                                  step=self._step_count, time_s=now,
                                  user=st.user, token=first, index=0,
                                  ttft_s=ttft))
        if first == cfg.eos_id or st.max_new <= 1:
            self._retire([slot], events, now)

    def _retire(self, slots: List[int], events: List[StreamEvent],
                now: float) -> None:
        table = np.asarray(jax.device_get(self._table))
        for slot in slots:
            st = self._slots[slot]
            res = RequestResult(
                uid=st.uid, slot=slot, prompt_len=st.prompt_len,
                bucket=st.bucket, user=st.user, tokens=list(st.tokens),
                logprob_sum=float(table[slot, 0]),
                stopped=bool(table[slot, 2] > 0),
                stop_step=self._step_count,
                ttft_s=st.ttft_s, latency_s=now - st.arrival_s)
            self.results[st.uid] = res
            self._slots[slot] = None
            self.stats.completed += 1
            events.append(StreamEvent(uid=st.uid, kind="done", slot=slot,
                                      step=self._step_count, time_s=now,
                                      user=st.user, result=res))

    # -- the rolling decode step --------------------------------------------

    def step(self) -> List[StreamEvent]:
        """Admit waiting requests into free slots, then advance the whole
        rolling population one token.  Returns the streamed events."""
        events: List[StreamEvent] = []
        self._admit(events)
        S = self.config.num_slots
        occupied = [i for i, s in enumerate(self._slots) if s is not None]
        if not occupied:
            return self._dispatch(events)

        cur = np.zeros((S, 1), np.int32)
        active = np.zeros((S,), bool)
        seeds = np.zeros((S,), np.int32)
        tok_idx = np.zeros((S,), np.int32)
        for i in occupied:
            st = self._slots[i]
            cur[i, 0] = st.cur
            active[i] = True
            seeds[i] = st.seed
            tok_idx[i] = st.n_gen
        self._cache, sampled, self._table = self._step_fn(
            self.backend.params, self._cache, jnp.asarray(cur),
            jnp.asarray(active), jnp.asarray(seeds), jnp.asarray(tok_idx),
            self._table)
        self._step_count += 1
        self.stats.steps += 1

        sampled_np = np.asarray(jax.device_get(sampled))
        now = self._clock()
        retired = []
        for i in occupied:
            st = self._slots[i]
            tok = int(sampled_np[i])
            index = st.n_gen
            st.tokens.append(tok)
            st.cur = tok
            self.stats.generated_tokens += 1
            events.append(StreamEvent(uid=st.uid, kind="token", slot=i,
                                      step=self._step_count, time_s=now,
                                      user=st.user, token=tok, index=index))
            if tok == self.config.eos_id or st.n_gen >= st.max_new:
                retired.append(i)
        if retired:
            self._retire(retired, events, now)
        return self._dispatch(events)

    def _dispatch(self, events: List[StreamEvent]) -> List[StreamEvent]:
        for ev in events:
            for consumer in self._consumers:
                consumer(ev)
        return events

    def run(self, *, max_steps: Optional[int] = None) -> Iterator[StreamEvent]:
        """Stream events until the queue and every slot drain."""
        steps = 0
        while self.pending or self.num_active:
            yield from self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(
                    f"engine did not drain within {max_steps} steps "
                    f"({self.pending} pending, {self.num_active} active)")
