"""Request batcher for the serving path: concurrent requests ARE records.

The paper's lens applied to serving: a decode step over B concurrent
requests is a MapReduce pass where each request is a record and its
per-request aggregates (logprob sum, token count, stop condition) are
monoid values keyed by request.  The batcher is the piece that makes that
literal — it groups pending requests into a :class:`DecodeBatch` whose
**slot index is the segment id** of the serve step's keyed fold
(``launch/serve.py``), so the whole batch aggregates in ONE
planner-lowered fold per decode step instead of a per-request loop.

Flush policies (both host-side, deterministic, injectable clock):

* **max_batch_size** — flush as soon as a full batch is pending (throughput:
  amortize the kernel launch across B requests, the serve-side analogue of
  the combiner amortizing the shuffle).
* **max_wait_s** — flush a partial batch once the OLDEST pending request has
  waited this long (latency: bound head-of-line blocking).  Partial batches
  still occupy ``num_slots`` segment ids; the empty slots are masked out of
  the fold with ``valid_mask`` — the ragged case, not a smaller compile.

The continuous engine uses the FIFO directly (``take()``): each step it
drains as many waiting requests as it has free slots, then groups them by
prefill SUFFIX bucket (prompt length minus cached-prefix length) into
shared ``(k, bucket)`` prefill programs on a declared power-of-two
k-ladder — grouping lives in the engine, not here, because a request's
bucket is only known after its prefix-cache lookup.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One decode request: a variable-length prompt plus a generation budget."""

    uid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    user: int = 0


@dataclasses.dataclass(frozen=True)
class DecodeBatch:
    """A flushed batch of ragged requests, slotted for the keyed fold.

    ``num_slots`` is the segment-id space of the serve step's fold (the
    batcher's max_batch_size, so every batch compiles to the same shapes);
    requests occupy slots [0, len(requests)).  ``pack`` pads the ragged
    prompts to a rectangle ONLY as the model-input layout — the validity
    mask rides along so every fold over the batch skips the padding.
    """

    requests: Tuple[Request, ...]
    num_slots: int

    def __post_init__(self):
        if not (0 < len(self.requests) <= self.num_slots):
            raise ValueError(
                f"{len(self.requests)} requests for {self.num_slots} slots")

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def segment_ids(self) -> np.ndarray:
        """slot id per request — THE segment ids of the serve step's fold."""
        return np.arange(self.num_slots, dtype=np.int32)

    @property
    def slot_valid(self) -> np.ndarray:
        """(num_slots,) bool: which slots hold a real request."""
        return np.arange(self.num_slots) < len(self.requests)

    def lengths(self) -> np.ndarray:
        """(num_slots,) prompt length per slot (0 for empty slots)."""
        out = np.zeros((self.num_slots,), np.int32)
        out[: len(self.requests)] = [len(r.prompt) for r in self.requests]
        return out

    def max_new(self) -> np.ndarray:
        """(num_slots,) generation budget per slot (0 for empty slots)."""
        out = np.zeros((self.num_slots,), np.int32)
        out[: len(self.requests)] = [r.max_new_tokens for r in self.requests]
        return out

    def pack(self, pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(tokens (num_slots, L), lengths (num_slots,), valid (num_slots, L)).

        L is the longest prompt in the batch; shorter prompts and empty
        slots are right-padded with ``pad_id`` and False in the mask.
        """
        lengths = self.lengths()
        L = max(1, int(lengths.max()))
        toks = np.full((self.num_slots, L), pad_id, np.int32)
        for i, r in enumerate(self.requests):
            toks[i, : len(r.prompt)] = r.prompt
        valid = np.arange(L)[None, :] < lengths[:, None]
        return toks, lengths, valid


@dataclasses.dataclass
class BatcherStats:
    enqueued: int = 0
    flushed_batches: int = 0
    flushed_requests: int = 0
    waited_flushes: int = 0     # flushes fired by the max-wait policy

    def fill_rate(self, max_batch_size: int) -> float:
        """Mean slot occupancy of flushed batches (1.0 = always full)."""
        if self.flushed_batches == 0:
            return 0.0
        return self.flushed_requests / (self.flushed_batches * max_batch_size)


class RequestBatcher:
    """FIFO enqueue/flush with max-batch-size and max-wait policies.

    ``clock`` is injectable (tests drive time by hand); requests flush in
    arrival order, and slot assignment within a batch is arrival order too,
    so segment ids are deterministic.
    """

    def __init__(self, max_batch_size: int = 8, max_wait_s: float = 0.010,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        self.max_batch_size = max_batch_size
        self.max_wait_s = max_wait_s
        self._clock = clock
        self._pending: Deque[Request] = deque()
        self._uids = itertools.count()
        self.stats = BatcherStats()

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, prompt: Sequence[int], *, max_new_tokens: int = 16,
               user: int = 0) -> int:
        """Enqueue one request; returns its uid."""
        uid = next(self._uids)
        self._pending.append(Request(uid=uid, prompt=tuple(int(t) for t in prompt),
                                     max_new_tokens=max_new_tokens,
                                     arrival_s=self._clock(), user=user))
        self.stats.enqueued += 1
        return uid

    def _policy(self) -> Tuple[bool, bool]:
        """(full, waited) — THE one definition of both flush policies,
        shared by :meth:`ready` and :meth:`flush` so they cannot diverge."""
        full = len(self._pending) >= self.max_batch_size
        waited = (not full and bool(self._pending)
                  and self._clock() - self._pending[0].arrival_s
                  >= self.max_wait_s)
        return full, waited

    def ready(self) -> bool:
        """True when a flush policy fires: full batch, or oldest waited out."""
        return any(self._policy())

    def take(self, n: int) -> Tuple[Request, ...]:
        """Pop up to ``n`` oldest pending requests (continuous-batching
        admission: one request per freed slot, arrival order preserved).

        Unlike :meth:`flush` this never waits on a policy — a free slot is
        capacity going idle, so admission is immediate.
        """
        if n < 0:
            raise ValueError("n must be >= 0")
        take = min(n, len(self._pending))
        reqs = tuple(self._pending.popleft() for _ in range(take))
        self.stats.flushed_requests += take
        return reqs

    def flush(self, *, force: bool = False) -> Optional[DecodeBatch]:
        """Pop the next batch when ready (or unconditionally with ``force``).

        Returns None when no batch is due.  Always at most
        ``max_batch_size`` requests; the batch keeps ``num_slots ==
        max_batch_size`` so every flush compiles to identical shapes and a
        partial batch is just a ragged (masked) one.
        """
        if not self._pending:
            return None
        full, waited = self._policy()
        if not (force or full or waited):
            return None
        take = min(len(self._pending), self.max_batch_size)
        reqs = tuple(self._pending.popleft() for _ in range(take))
        self.stats.flushed_batches += 1
        self.stats.flushed_requests += take
        if waited:               # forced partials don't count as policy fires
            self.stats.waited_flushes += 1
        return DecodeBatch(requests=reqs, num_slots=self.max_batch_size)
