"""Fault-tolerance control plane: preemption, elastic re-mesh, stragglers.

This is the part of a 1000+-node deployment that is pure control logic — it
is exercised here against simulated signals/timings (tests/test_runtime.py),
and its decisions (mesh shapes, excluded hosts, checkpoint cadence) are the
same ones a real TPU fleet controller would apply.

Monoid tie-ins (DESIGN.md §2):
* restart = combine(checkpointed aggregate, new partial aggregate);
* elastic re-mesh re-brackets the data-parallel reduction over a different
  axis size — legal because gradient/metric aggregation is associative and
  commutative;
* straggler-tolerant aggregation can combine the K fastest shards' partial
  metrics first and fold in late arrivals — again only legal for monoids.
"""
from __future__ import annotations

import dataclasses
import math
import signal
import threading
from typing import Callable, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit flag.

    On Cloud TPU, maintenance events arrive as SIGTERM with a grace window;
    the train loop polls ``should_stop`` each step and saves before exiting.
    """

    def __init__(self, signals: Sequence[int] = (signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._on_signal)

    def _on_signal(self, signum, frame):
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:         # for tests / manual drain
        self._flag.set()

    def restore(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_hosts: int
    global_batch_scale: float   # rescale factor vs the nominal batch


def plan_remesh(healthy_devices: int, *, model_parallel: int = 16,
                pods: int = 1, nominal_data: int = 16) -> Optional[MeshPlan]:
    """Largest (pod, data, model) mesh that fits the surviving devices.

    Keeps the model axis fixed (TP degree is a property of the model fit) and
    shrinks the data axis to the largest power of two that fits — training
    continues at reduced global batch (scale reported so the caller can adjust
    LR / accumulation). Returns None if even (1, model_parallel) doesn't fit.
    """
    per_pod = healthy_devices // max(pods, 1)
    data = per_pod // model_parallel
    if data < 1:
        return None
    data = 1 << int(math.floor(math.log2(data)))
    shape: Tuple[int, ...]
    if pods > 1:
        shape, axes = (pods, data, model_parallel), ("pod", "data", "model")
        used = pods * data * model_parallel
    else:
        shape, axes = (data, model_parallel), ("data", "model")
        used = data * model_parallel
    return MeshPlan(shape=shape, axes=axes,
                    dropped_hosts=healthy_devices - used,
                    global_batch_scale=(pods * data) / max(nominal_data * pods, 1))


class ElasticController:
    """Decides when to re-mesh: on failure, shrink; on recovery, grow.

    ``on_remesh(plan)`` is the integration point: rebuild the mesh, re-jit
    the step (same code — only the mesh object changes), and restore state
    from the latest checkpoint with the new shardings
    (CheckpointStore.restore(shardings=...) is mesh-agnostic).
    """

    def __init__(self, total_devices: int, *, model_parallel: int = 16,
                 pods: int = 1, on_remesh: Optional[Callable] = None):
        self.total = total_devices
        self.model_parallel = model_parallel
        self.pods = pods
        self.healthy = total_devices
        self.on_remesh = on_remesh
        self.current = plan_remesh(total_devices, model_parallel=model_parallel,
                                   pods=pods)
        self.suspects: List[int] = []   # overlap-collapse early warnings
        self._downed: set = set()       # hosts already counted as failed

    def report_failure(self, num_devices: int) -> Optional[MeshPlan]:
        self.healthy = max(0, self.healthy - num_devices)
        return self._maybe_remesh()

    def ingest(self, report: StragglerReport, *,
               devices_per_host: int = 1) -> Optional[MeshPlan]:
        """Consume a :class:`StragglerReport` (from ``observe_stats``).

        Hosts flagged slow (EWMA past threshold for ``patience`` steps) are
        treated as failed and may trigger a re-mesh; hosts whose overlap
        merely collapsed this step become ``suspects`` — the pre-timeout
        warning a scheduler acts on (drain, re-balance input shards) without
        yet shrinking the mesh.
        """
        self.suspects = sorted(set(report.collapsing_hosts)
                               - set(report.slow_hosts) - self._downed)
        newly = [h for h in report.slow_hosts if h not in self._downed]
        if not newly:
            return None
        self._downed.update(newly)
        return self.report_failure(len(newly) * devices_per_host)

    def report_recovery(self, num_devices: int) -> Optional[MeshPlan]:
        self.healthy = min(self.total, self.healthy + num_devices)
        return self._maybe_remesh()

    def _maybe_remesh(self) -> Optional[MeshPlan]:
        plan = plan_remesh(self.healthy, model_parallel=self.model_parallel,
                           pods=self.pods)
        if plan is None:
            raise RuntimeError(
                f"unrecoverable: {self.healthy} devices cannot host "
                f"model_parallel={self.model_parallel}")
        if self.current is None or plan.shape != self.current.shape:
            self.current = plan
            if self.on_remesh:
                self.on_remesh(plan)
            return plan
        return None


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerReport:
    step: int
    slow_hosts: List[int]
    median_s: float
    threshold_s: float
    # hosts whose measured shuffle overlap collapsed below the model this
    # step — the EARLY signal: a slow host drags the pipelined DCN crossing
    # out from under everyone's compute (overlap fraction drops fleet-wide,
    # worst at the culprit) several steps before its EWMA step time trips
    # the timeout threshold above.  Empty when stats carry no overlap data.
    collapsing_hosts: List[int] = dataclasses.field(default_factory=list)
    median_overlap: Optional[float] = None


class StragglerMonitor:
    """EWMA per-host step-time tracking with a median-multiple threshold.

    A host whose smoothed step time exceeds ``ratio x`` the fleet median for
    ``patience`` consecutive steps is flagged. The controller's actions (in
    order): (1) re-balance input sharding away from the host's data shard,
    (2) raise checkpoint cadence, (3) treat as failed -> elastic re-mesh.
    On real fleets the timings come from per-host step barriers; tests feed
    synthetic timings.
    """

    def __init__(self, num_hosts: int, *, alpha: float = 0.3,
                 ratio: float = 1.5, patience: int = 3,
                 collapse_ratio: float = 0.5):
        self.alpha = alpha
        self.ratio = ratio
        self.patience = patience
        # a host whose measured overlap falls below collapse_ratio x its
        # modeled overlap is flagged immediately (no patience): overlap
        # collapse is a leading indicator, timeouts a trailing one
        self.collapse_ratio = collapse_ratio
        self.ewma = [0.0] * num_hosts
        self.strikes = [0] * num_hosts
        self.step = 0

    def observe(self, step_times: Sequence[float]) -> StragglerReport:
        self.step += 1
        for i, t in enumerate(step_times):
            self.ewma[i] = t if self.ewma[i] == 0.0 else \
                self.alpha * t + (1 - self.alpha) * self.ewma[i]
        med = sorted(self.ewma)[len(self.ewma) // 2]
        thr = self.ratio * med
        slow = []
        for i, e in enumerate(self.ewma):
            if e > thr:
                self.strikes[i] += 1
                if self.strikes[i] >= self.patience:
                    slow.append(i)
            else:
                self.strikes[i] = 0
        return StragglerReport(step=self.step, slow_hosts=slow,
                               median_s=med, threshold_s=thr)

    def observe_stats(self, per_host_stats: Sequence) -> StragglerReport:
        """Feed one ``core.mapreduce.ShuffleStats`` per host for this step.

        Step times come from ``measured_us`` (falling back to the model when
        a host reported none) and flow through the EWMA/patience machinery
        of :meth:`observe`.  Additionally, hosts running an overlapped
        (async) plan whose ``overlap_measured`` fell below
        ``collapse_ratio x overlap_modeled`` are flagged as collapsing THIS
        step — the same per-step record the benchmarks emit doubles as the
        health signal, and a struggling host is visible here while its step
        time is still inside the timeout threshold.
        """
        times = [
            (s.measured_us if s.measured_us is not None else s.predicted_us)
            / 1e6
            for s in per_host_stats]
        report = self.observe(times)
        collapsing = []
        overlaps = []
        for i, s in enumerate(per_host_stats):
            if s.overlap_modeled > 0.0 and s.overlap_measured is not None:
                overlaps.append(s.overlap_measured)
                if s.overlap_measured < self.collapse_ratio * s.overlap_modeled:
                    collapsing.append(i)
        report.collapsing_hosts = collapsing
        if overlaps:
            report.median_overlap = sorted(overlaps)[len(overlaps) // 2]
        return report


# ---------------------------------------------------------------------------
# checkpoint cadence
# ---------------------------------------------------------------------------

def checkpoint_interval(step_time_s: float, *, mtbf_hours: float = 24.0,
                        num_nodes: int = 1000, write_time_s: float = 30.0) -> int:
    """Young/Daly optimal checkpoint interval, in steps.

    t_opt = sqrt(2 * write_time * MTBF_system); MTBF_system = MTBF_node/nodes.
    At 1000 nodes x 24h MTBF => system MTBF 86s?? -- no: 86400*24/1000 ~ 86s
    would make training impossible; realistic node MTBF is years. The point
    of exposing the formula is that cadence is *derived*, not hard-coded.
    """
    mtbf_system_s = mtbf_hours * 3600.0 / max(num_nodes, 1)
    t_opt_s = math.sqrt(2.0 * write_time_s * mtbf_system_s)
    return max(1, int(t_opt_s / max(step_time_s, 1e-6)))
