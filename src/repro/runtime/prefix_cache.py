"""Radix prefix KV cache with monoid-fold bookkeeping.

Concurrent requests share prompt prefixes — system prompts, few-shot
templates — yet a cold admission re-prefills the whole prompt.  This module
caches the KV rows of previously-prefilled prompts in a *block-quantized
radix trie* over token ids: each trie node owns exactly ``block`` tokens'
worth of KV rows (host-side numpy, one array per cache leaf), so the
longest cached prefix of a new prompt is a trie walk, and admission only
prefills the remaining suffix (``runtime/engine.py`` buckets on *suffix*
length, so TTFT drops proportionally).

The paper's angle is the bookkeeping.  Hit counting, byte-level memory
accounting, and the eviction score are all columns of ONE per-node monoid
state — :func:`repro.core.monoids.cache_stats`, a :func:`product` of two
additive columns and a :func:`decayed_lru` score — and the stats table
(keyed by trie-node id) updates with a single planner-lowered keyed fold
(:func:`repro.core.plan.execute_fold`, ``node id == segment id``) per
engine step, exactly the shape of the engine's per-request metrics fold.
Host code appends event rows (hit, insert) as they happen;
:meth:`PrefixCache.flush_stats` folds them in fixed-width chunks so the
fold compiles once.  Eviction reads the table back, re-anchors the decayed
scores to now (:func:`repro.core.monoids.decayed_value`), and removes the
lowest-scoring childless node — decayed-LRU with smooth aging, no
timestamps stored host-side.

The trie is payload-agnostic: the engine hands it opaque lists of numpy
arrays per block (one per KV cache leaf), so the same cache serves the toy
test backend and the real model substrate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import monoids
from ..core.plan import execute_fold


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """Sizing/behaviour of the prefix cache.

    block: tokens per trie node (prefix hits are quantized to multiples).
    capacity: max trie nodes == rows of the stats table (segment-id space).
    max_bytes: resident-KV byte budget (None = bounded by capacity only).
    half_life_s: decayed-LRU half life of the eviction score.
    events_per_fold: fixed row count of one stats fold (events are padded
      to this width with masked identity rows, so the fold compiles once).
    """

    block: int = 4
    capacity: int = 256
    max_bytes: Optional[int] = None
    half_life_s: float = 60.0
    events_per_fold: int = 64

    def __post_init__(self):
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.max_bytes is not None and self.max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {self.max_bytes}")
        if self.half_life_s <= 0:
            raise ValueError(
                f"half_life_s must be positive, got {self.half_life_s}")
        if self.events_per_fold < 1:
            raise ValueError(
                f"events_per_fold must be >= 1, got {self.events_per_fold}")


@dataclasses.dataclass
class PrefixHit:
    """Longest cached block-aligned prefix of a prompt.

    length: tokens covered (multiple of ``block``; 0 = miss).
    blocks: per-block KV payloads, each a list of numpy arrays in the
      engine's cache-leaf order.
    node_ids: stats-table row per block (hit events were recorded).
    nbytes: resident bytes of the reused payloads (the bytes NOT re-prefilled).
    """

    length: int
    blocks: List[List[np.ndarray]]
    node_ids: List[int]
    nbytes: int


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0
    hits: int = 0                 # lookups that matched >= 1 block
    hit_tokens: int = 0           # prompt tokens served from the cache
    prompt_tokens: int = 0        # all prompt tokens seen by lookup()
    bytes_saved: int = 0          # KV bytes not re-prefilled
    inserted_nodes: int = 0
    evictions: int = 0
    folds: int = 0                # planner folds executed
    fold_rows: int = 0            # event rows folded (excl. padding)

    def hit_rate(self) -> float:
        """Fraction of prompt tokens served from the cache."""
        return self.hit_tokens / max(self.prompt_tokens, 1)


class _Node:
    __slots__ = ("key", "node_id", "payload", "nbytes", "parent", "children")

    def __init__(self, key, node_id, payload, nbytes, parent):
        self.key = key                  # tuple of `block` token ids
        self.node_id = node_id          # row in the stats table
        self.payload = payload          # list of np arrays (KV rows)
        self.nbytes = nbytes
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}


class PrefixCache:
    """Block-quantized radix trie over tokenized prompts; KV rows per node;
    all bookkeeping through one keyed monoid fold (see module docstring)."""

    def __init__(self, config: PrefixCacheConfig = PrefixCacheConfig(), *,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config
        self.monoid = monoids.cache_stats(config.half_life_s)
        self._clock = clock
        self._root = _Node(key=None, node_id=-1, payload=None, nbytes=0,
                           parent=None)
        self._nodes: Dict[int, _Node] = {}
        self._free = list(range(config.capacity - 1, -1, -1))   # pop() -> 0 first
        self._bytes = 0      # host mirror of the table's bytes column
        self.stats = PrefixCacheStats()
        # pending event rows: (node_id, hits, bytes, score_weight, score_t)
        self._pending: List[Tuple[int, float, float, float, float]] = []
        C = config.capacity
        self._table = {
            "bytes": jnp.zeros((C,), jnp.float32),
            "hits": jnp.zeros((C,), jnp.float32),
            "score": (jnp.zeros((C,), jnp.float32),
                      jnp.full((C,), -jnp.inf, jnp.float32)),
        }
        m = self.monoid

        def fold_impl(table, ids, hits, nbytes, sw, st, valid):
            rows = {"bytes": nbytes, "hits": hits, "score": (sw, st)}
            return execute_fold(m, rows, segment_ids=ids, num_segments=C,
                                valid_mask=valid, init=table)

        self._fold_fn = jax.jit(fold_impl)

        def clear_impl(table, nid):
            # reset one row to the identity: the monoid-consistent way to
            # retire a node id — the bytes column drops by the node's bytes,
            # so sum(bytes) keeps equalling resident bytes
            return {
                "bytes": table["bytes"].at[nid].set(0.0),
                "hits": table["hits"].at[nid].set(0.0),
                "score": (table["score"][0].at[nid].set(0.0),
                          table["score"][1].at[nid].set(-jnp.inf)),
            }

        self._clear_fn = jax.jit(clear_impl)

    # -- sizes --------------------------------------------------------------

    @property
    def node_count(self) -> int:
        return len(self._nodes)

    @property
    def total_bytes(self) -> int:
        """Resident KV bytes (host mirror; equals the table's bytes sum)."""
        return self._bytes

    # -- the keyed stats fold ----------------------------------------------

    def _event(self, nid: int, hits: float, nbytes: float, weight: float,
               t: float) -> None:
        self._pending.append((nid, hits, nbytes, weight, t))

    def flush_stats(self) -> int:
        """Fold pending event rows into the stats table.

        Called once per engine step: all of a step's cache events reduce in
        ONE fixed-shape keyed fold (more only if a step produced more than
        ``events_per_fold`` rows).  Padding rows are masked to the identity
        via ``valid_mask``.  Returns the number of folds run.
        """
        E = self.config.events_per_fold
        n = 0
        while self._pending:
            chunk = self._pending[:E]
            del self._pending[:E]
            ids = np.zeros((E,), np.int32)
            hits = np.zeros((E,), np.float32)
            nb = np.zeros((E,), np.float32)
            sw = np.zeros((E,), np.float32)
            st = np.full((E,), -np.inf, np.float32)   # identity anchor
            valid = np.zeros((E,), bool)
            for i, (nid, h, b, w, t) in enumerate(chunk):
                ids[i], hits[i], nb[i], sw[i], st[i] = nid, h, b, w, t
                valid[i] = True
            self._table = self._fold_fn(
                self._table, jnp.asarray(ids), jnp.asarray(hits),
                jnp.asarray(nb), jnp.asarray(sw), jnp.asarray(st),
                jnp.asarray(valid))
            n += 1
            self.stats.folds += 1
            self.stats.fold_rows += len(chunk)
        return n

    def table(self) -> Dict:
        """The folded stats table (host), pending events flushed."""
        self.flush_stats()
        return jax.device_get(self._table)

    def accounted_bytes(self) -> int:
        """Resident bytes as accounted by the fold (sum of the bytes
        column) — bit-equal to :attr:`total_bytes` by construction."""
        return int(round(float(np.sum(np.asarray(self.table()["bytes"])))))

    def scores(self, now: float) -> np.ndarray:
        """(capacity,) decayed eviction scores re-anchored to ``now``."""
        self.flush_stats()
        val = monoids.decayed_value(self._table["score"], now,
                                    self.config.half_life_s)
        return np.asarray(jax.device_get(val))

    def compile_counts(self) -> Dict[str, int]:
        def n(f):
            try:
                return int(f._cache_size())
            except Exception:      # pragma: no cover - older jax
                return -1

        return {"prefix_stats_fold": n(self._fold_fn),
                "prefix_row_reset": n(self._clear_fn)}

    # -- lookup / insert / evict -------------------------------------------

    def lookup(self, prompt: Sequence[int]) -> PrefixHit:
        """Longest cached block-aligned prefix STRICTLY shorter than the
        prompt (at least one token must remain to prefill: the suffix
        decode produces the first sampled token's logits)."""
        B = self.config.block
        self.stats.lookups += 1
        self.stats.prompt_tokens += len(prompt)
        limit = max(len(prompt) - 1, 0) // B
        node = self._root
        blocks: List[List[np.ndarray]] = []
        ids: List[int] = []
        nbytes = 0
        for i in range(limit):
            child = node.children.get(
                tuple(int(t) for t in prompt[i * B:(i + 1) * B]))
            if child is None:
                break
            node = child
            blocks.append(child.payload)
            ids.append(child.node_id)
            nbytes += child.nbytes
        t = float(self._clock())
        for nid in ids:
            self._event(nid, 1.0, 0.0, 1.0, t)
        length = len(blocks) * B
        if length:
            self.stats.hits += 1
            self.stats.hit_tokens += length
            self.stats.bytes_saved += nbytes
        return PrefixHit(length=length, blocks=blocks, node_ids=ids,
                         nbytes=nbytes)

    def insert(self, prompt: Sequence[int],
               payload: Callable[[int], List[np.ndarray]], *,
               max_blocks: Optional[int] = None) -> int:
        """Insert the full-block prefixes of ``prompt`` into the trie.

        ``payload(i)`` materializes block i's KV rows (list of np arrays) —
        called only for blocks not already cached.  Returns the number of
        new nodes.  Evicts (childless, lowest decayed score first) when the
        node capacity or byte budget would overflow; nodes on the path
        being inserted are protected.
        """
        B = self.config.block
        n = len(prompt) // B
        if max_blocks is not None:
            n = min(n, max_blocks)
        node = self._root
        t = float(self._clock())
        protect = set()
        created = 0
        for i in range(n):
            key = tuple(int(x) for x in prompt[i * B:(i + 1) * B])
            child = node.children.get(key)
            if child is None:
                child = self._new_node(node, key, payload(i), t, protect)
                if child is None:
                    break          # budget exhausted, nothing evictable
                created += 1
            protect.add(child.node_id)
            node = child
        return created

    def _new_node(self, parent: _Node, key, arrays: List[np.ndarray],
                  t: float, protect) -> Optional[_Node]:
        nbytes = int(sum(int(a.nbytes) for a in arrays))
        mb = self.config.max_bytes
        if mb is not None and nbytes > mb:
            return None
        if not self._free and not self._evict_one(protect):
            return None
        while mb is not None and self._bytes + nbytes > mb:
            if not self._evict_one(protect):
                return None
        nid = self._free.pop()
        node = _Node(key=key, node_id=nid, payload=list(arrays),
                     nbytes=nbytes, parent=parent)
        parent.children[key] = node
        self._nodes[nid] = node
        self._bytes += nbytes
        self.stats.inserted_nodes += 1
        # insertion event: bytes land in the accounting column, the score
        # anchors at now (a fresh node is as warm as a fresh hit)
        self._event(nid, 0.0, float(nbytes), 1.0, t)
        return node

    def evict(self, n: int = 1) -> int:
        """Evict up to ``n`` nodes (childless, lowest decayed score first).
        Returns how many were evicted."""
        done = 0
        while done < n and self._evict_one(frozenset()):
            done += 1
        return done

    def _evict_one(self, protect) -> bool:
        # pending hit events move scores: fold them BEFORE choosing a victim
        # (also: no pending row may reference the id we are about to free)
        self.flush_stats()
        candidates = [nd for nd in self._nodes.values()
                      if not nd.children and nd.node_id not in protect]
        if not candidates:
            return False
        scores = self.scores(float(self._clock()))
        victim = min(candidates,
                     key=lambda nd: (float(scores[nd.node_id]), nd.node_id))
        del victim.parent.children[victim.key]
        del self._nodes[victim.node_id]
        self._bytes -= victim.nbytes
        self._table = self._clear_fn(self._table, jnp.int32(victim.node_id))
        self._free.append(victim.node_id)
        self.stats.evictions += 1
        return True
