"""repro.data — deterministic resumable pipeline + monoid stream statistics."""
from .pipeline import DataConfig, Prefetcher, SyntheticCorpus, packed_stats
from .stats import (init_stats, make_stream_stats, summarize, sync_stats,
                    update_stats)
from .windows import (SlidingWindow, TumblingWindow, WindowedMetrics,
                      WindowResult, session_fold, sessionize, tumbling_fold,
                      tumbling_ids)
