"""Streaming corpus statistics as one product monoid (paper §3).

One accumulator tracks, over the token stream:
  * ``cms``   — count-min sketch of token frequencies (approximate counts),
  * ``hll``   — HyperLogLog of distinct token ids,
  * ``bloom`` — Bloom filter of seen ids (membership),
  * ``count`` — exact token count,

combined per batch with in-mapper combining (Algorithm 4: one fold per batch,
state carried across batches), and across hosts with ONE collective over the
product monoid. This is the Summingbird observation (paper §4): the same
monoid serves the streaming pipeline and any batch job.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..core import monoids
from ..core.monoid import Monoid


def make_stream_stats(*, cms_depth: int = 4, cms_width: int = 2048,
                      hll_precision: int = 10,
                      bloom_bits: int = 1 << 14) -> Monoid:
    return monoids.product(
        cms=monoids.count_min(cms_depth, cms_width),
        hll=monoids.hyperloglog(hll_precision),
        bloom=monoids.bloom_filter(bloom_bits),
        count=monoids.count,
    )


def init_stats(m: Monoid) -> Dict[str, Any]:
    return m.identity()


@jax.jit
def _fold_tokens(state, tokens):
    """In-mapper combine of one token batch into the stats state."""
    flat = tokens.reshape(-1)
    cms = monoids.cms_update_batch(state["cms"], flat)
    hll = monoids.hll_update_batch(state["hll"], flat)
    # bloom: batch OR of per-hash one-hots
    nb = state["bloom"].shape[-1]
    bloom = state["bloom"]
    for s in range(4):
        idx = monoids._uhash(flat, s) % nb
        bloom = bloom.at[idx].set(1)
    count = state["count"] + flat.shape[0]
    return {"cms": cms, "hll": hll, "bloom": bloom, "count": count}


def update_stats(state: Dict[str, Any], tokens: jnp.ndarray) -> Dict[str, Any]:
    return _fold_tokens(state, tokens)


def summarize(m: Monoid, state: Dict[str, Any]) -> Dict[str, Any]:
    """extract(): approximate distinct count, total, heavy-hitter counts."""
    out = m.extract(state)
    return {"tokens": int(out["count"]),
            "approx_distinct": float(out["hll"]),
            "cms": state["cms"], "bloom": state["bloom"]}
