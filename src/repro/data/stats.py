"""Streaming corpus statistics as one product monoid (paper §3).

One accumulator tracks, over the token stream:
  * ``cms``   — count-min sketch of token frequencies (approximate counts),
  * ``hll``   — HyperLogLog of distinct token ids,
  * ``bloom`` — Bloom filter of seen ids (membership),
  * ``count`` — exact token count,

combined per batch with in-mapper combining (Algorithm 4: the whole batch is
vector-lifted into ONE monoid value, then folded into the carried state by
the execution planner), and across hosts with ONE collective over the
product monoid (:func:`sync_stats`).  This is the Summingbird observation
(paper §4): the same monoid serves the streaming pipeline and any batch job.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from ..core import monoids
from ..core.monoid import Monoid
from ..core.plan import execute_fold


def make_stream_stats(*, cms_depth: int = 4, cms_width: int = 2048,
                      hll_precision: int = 10,
                      bloom_bits: int = 1 << 14) -> Monoid:
    return monoids.product(
        cms=monoids.count_min(cms_depth, cms_width),
        hll=monoids.hyperloglog(hll_precision),
        bloom=monoids.bloom_filter(bloom_bits),
        count=monoids.count,
    )


def init_stats(m: Monoid) -> Dict[str, Any]:
    return m.identity()


# Structural combine for any stream-stats state: parameter-free (widths come
# from the state's shapes), so the jit'd fold needs no Monoid argument.
_STATS_COMBINE = Monoid(
    name="stream_stats",
    combine=lambda a, b: {
        "cms": a["cms"] + b["cms"],
        "hll": jnp.maximum(a["hll"], b["hll"]),
        "bloom": jnp.bitwise_or(a["bloom"], b["bloom"]),
        "count": a["count"] + b["count"],
    },
    identity_fn=lambda *, example: jax.tree_util.tree_map(
        jnp.zeros_like, example),
)


def _batch_value(state: Dict[str, Any], tokens: jnp.ndarray,
                 valid_mask: jnp.ndarray | None = None) -> Dict[str, Any]:
    """Vector-lift a whole token batch into ONE stats monoid value.

    This is the mapper side done in bulk: shapes are taken from ``state`` so
    the value matches whatever widths ``make_stream_stats`` chose.
    ``valid_mask`` (same shape as ``tokens``) is the ragged path: padding
    tokens contribute the identity to every component — the same mask
    convention the execution planner's ``valid_mask=`` uses.
    """
    flat = tokens.reshape(-1)
    mask = None if valid_mask is None else jnp.asarray(valid_mask,
                                                       jnp.bool_).reshape(-1)
    weights = None if mask is None else mask.astype(jnp.int32)
    cms = monoids.cms_update_batch(jnp.zeros_like(state["cms"]), flat,
                                   weights=weights)
    hll = monoids.hll_update_batch(jnp.zeros_like(state["hll"]), flat,
                                   valid_mask=mask)
    bloom = jnp.zeros_like(state["bloom"])
    hit = (jnp.ones_like(flat, bloom.dtype) if mask is None
           else mask.astype(bloom.dtype))
    for s in range(4):
        idx = monoids._uhash(flat, s) % bloom.shape[-1]
        bloom = bloom.at[idx].max(hit)    # masked-out tokens set no bits
    count = (jnp.asarray(flat.shape[0], state["count"].dtype) if mask is None
             else jnp.sum(mask).astype(state["count"].dtype))
    return {"cms": cms, "hll": hll, "bloom": bloom, "count": count}


@jax.jit
def _fold_tokens(state, tokens, valid_mask=None):
    """In-mapper combine of one token batch into the stats state, lowered
    through the execution planner (tree fold over [state, batch_value])."""
    bval = _batch_value(state, tokens, valid_mask)
    stacked = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]),
                                     state, bval)
    return execute_fold(_STATS_COMBINE, stacked)


def update_stats(state: Dict[str, Any], tokens: jnp.ndarray,
                 valid_mask: jnp.ndarray | None = None) -> Dict[str, Any]:
    """Fold one (possibly ragged) token batch into the stats state.

    With ``valid_mask`` only True positions count — the data pipeline's
    packed/padded batches feed straight in, no rectangular re-batching.
    """
    return _fold_tokens(state, tokens, valid_mask)


def sync_stats(m: Monoid, state: Dict[str, Any],
               mesh_axes: Sequence[Any]) -> Dict[str, Any]:
    """Combine per-host stats across mesh axes (inside shard_map) — ONE
    collective for the whole product monoid, ICI first then DCN."""
    return execute_fold(
        m, jax.tree_util.tree_map(lambda v: v[None], state),
        mesh_axes=mesh_axes)


def summarize(m: Monoid, state: Dict[str, Any]) -> Dict[str, Any]:
    """extract(): approximate distinct count, total, heavy-hitter counts."""
    out = m.extract(state)
    return {"tokens": int(out["count"]),
            "approx_distinct": float(out["hll"]),
            "cms": state["cms"], "bloom": state["bloom"]}
