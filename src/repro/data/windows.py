"""Windowed streaming analytics: sliding/tumbling monoid windows,
sessionization, and live per-user serving metrics.

The paper's principle extended from batch folds to *infinite streams*: a
window aggregate is just a merge tree of partial monoid states, so the same
``(combine, identity)`` pair that powers the batch planner powers every
window shape here — no inverses required, which is what lets the
non-invertible zoo (max, CMS, HLL, top-k, decayed-LRU) slide.

Three window shapes, three execution strategies, one algebra:

* :class:`SlidingWindow` — the **two-stacks / flip-when-empty** trick:
  a FIFO window maintained as two stacks of partial aggregates.  Each event
  costs O(1) amortized combines (one on push, one when its stack flips),
  and eviction never needs ``combine``'s inverse — the evicted element was
  never folded into the front stack's suffix aggregates in the first place.
* :class:`TumblingWindow` / :func:`tumbling_fold` — fixed-width time
  buckets.  The streaming class closes windows as event time advances; the
  batch function lowers the whole stream through the execution planner
  (:func:`repro.core.plan.execute_fold`) with **window id == segment id**,
  so tumbling aggregation rides the same kernel/segment-ops/scan tiers and
  mesh collectives as every other keyed fold.
* :func:`sessionize` / :func:`session_fold` — per-user sessions split on
  inactivity gaps, with **session id == segment id**: per-session combines
  are one planner-lowered keyed fold, and per-host session tables merge
  across the fleet with ``data.stats.sync_stats`` (sessions are disjoint
  or monoid-mergeable, so the cross-host combine is exact).

:class:`WindowedMetrics` is the serving consumer: subscribe it to a
:class:`repro.runtime.engine.ContinuousEngine` and every stream event folds
into per-user sliding windows (latency/TTFT/tokens via the mean-pair
monoid), per-user decayed token-rate scores (``monoids.decayed_sum``), and
a fleet-wide tumbling token counter — live analytics with O(window) state
per user, any traffic volume.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import monoids
from ..core.monoid import Monoid, Pytree
from ..core.plan import execute_fold


# ---------------------------------------------------------------------------
# sliding windows — the two-stacks trick
# ---------------------------------------------------------------------------

class SlidingWindow:
    """Aggregate of the last ``size`` events, O(1) amortized combines/event.

    Two stacks of partial monoid states:

    * ``back`` — raw lifted values in arrival order, plus their running
      aggregate (``push`` costs one combine);
    * ``front`` — suffix aggregates built when an eviction finds the front
      empty: the back stack is *flipped*, each popped value combined onto
      an accumulator so entry ``i`` stores ``fold(v_i .. v_newest)`` in
      stream order.  The flip costs one combine per element, and each
      element flips at most once — O(1) amortized.

    ``query() == combine(front_top, back_agg)`` preserves stream order, so
    non-commutative monoids (``concat``, ``affine_scan``) are safe; and no
    step ever *removes* a value from an aggregate, so non-invertible
    monoids (max, CMS, HLL, decayed-LRU) are safe too — the property the
    brute-force differential oracle in tests/test_windows.py pins.

    ``example=`` seeds the identity for queries before the first push;
    otherwise the identity is derived from the first pushed value.
    """

    def __init__(self, m: Monoid, size: int, *,
                 example: Optional[Pytree] = None):
        if size < 1:
            raise ValueError(f"window size must be >= 1, got {size}")
        self.monoid = m
        self.size = int(size)
        self._front: List[Pytree] = []    # suffix aggregates, top = oldest
        self._back: List[Pytree] = []     # raw values, arrival order
        self._back_agg: Optional[Pytree] = None
        self._identity = None if example is None else m.identity_like(example)
        self.pushes = 0
        self.flip_combines = 0            # telemetry: amortization is visible

    def __len__(self) -> int:
        return len(self._front) + len(self._back)

    def _e(self) -> Pytree:
        if self._identity is None:
            raise ValueError(
                "query on an empty SlidingWindow with no identity: pass "
                "example= at construction or push a value first")
        return self._identity

    def push(self, value: Pytree, *, lifted: bool = True) -> None:
        """Fold one event in; evicts the oldest when the window is full."""
        v = value if lifted else self.monoid.lift(value)
        if self._identity is None:
            self._identity = self.monoid.identity_like(v)
        if len(self) == self.size:
            self.evict()
        self._back.append(v)
        self._back_agg = (v if self._back_agg is None
                          else self.monoid.combine(self._back_agg, v))
        self.pushes += 1

    def evict(self) -> None:
        """Drop the oldest event (flip the back stack if front is empty)."""
        if not self._front:
            acc = self._e()
            while self._back:
                acc = self.monoid.combine(self._back.pop(), acc)
                self._front.append(acc)
                self.flip_combines += 1
            self._back_agg = None
        if not self._front:
            raise ValueError("evict from an empty window")
        self._front.pop()

    def query(self) -> Pytree:
        """The window aggregate (the identity when empty)."""
        front = self._front[-1] if self._front else None
        if front is None and self._back_agg is None:
            return self._e()
        if front is None:
            return self._back_agg
        if self._back_agg is None:
            return front
        return self.monoid.combine(front, self._back_agg)

    def extract(self) -> Pytree:
        return self.monoid.extract(self.query())


# ---------------------------------------------------------------------------
# tumbling windows — streaming and planner-lowered batch forms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WindowResult:
    """One closed window: [start, end) and its folded monoid value."""

    index: int
    start: float
    end: float
    value: Pytree


class TumblingWindow:
    """Fixed-width time windows over a time-ordered stream.

    ``push(value, t)`` folds the event into the open window and returns the
    list of :class:`WindowResult` it closed (empty windows are skipped).
    ``flush()`` closes and returns the open window, if any.
    """

    def __init__(self, m: Monoid, width: float, *, t0: float = 0.0,
                 example: Optional[Pytree] = None):
        if width <= 0:
            raise ValueError(f"window width must be positive, got {width}")
        self.monoid = m
        self.width = float(width)
        self.t0 = float(t0)
        self._idx: Optional[int] = None   # open window index
        self._state: Optional[Pytree] = None
        self._identity = None if example is None else m.identity_like(example)
        self.events = 0

    def _window_of(self, t: float) -> int:
        return int(math.floor((float(t) - self.t0) / self.width))

    def _close(self) -> WindowResult:
        res = WindowResult(index=self._idx,
                           start=self.t0 + self._idx * self.width,
                           end=self.t0 + (self._idx + 1) * self.width,
                           value=self._state)
        self._idx, self._state = None, None
        return res

    def push(self, value: Pytree, t: float, *,
             lifted: bool = True) -> List[WindowResult]:
        v = value if lifted else self.monoid.lift(value)
        if self._identity is None:
            self._identity = self.monoid.identity_like(v)
        w = self._window_of(t)
        closed: List[WindowResult] = []
        if self._idx is not None and w < self._idx:
            raise ValueError(
                f"event at t={t} precedes the open window "
                f"[{self.t0 + self._idx * self.width}, ...): tumbling "
                "windows need a time-ordered stream")
        if self._idx is not None and w > self._idx:
            closed.append(self._close())
        if self._idx is None:
            self._idx, self._state = w, self.monoid.identity_like(v)
        self._state = self.monoid.combine(self._state, v)
        self.events += 1
        return closed

    def flush(self) -> List[WindowResult]:
        """Close the open window (end-of-stream)."""
        return [self._close()] if self._idx is not None else []


def tumbling_ids(timestamps, *, width: float, t0: float = 0.0) -> jnp.ndarray:
    """Window index per event — the segment ids of a tumbling fold."""
    ts = jnp.asarray(timestamps, jnp.float32)
    return jnp.floor((ts - t0) / width).astype(jnp.int32)


def tumbling_fold(m: Monoid, values: Pytree, timestamps, *, width: float,
                  num_windows: int, t0: float = 0.0, valid_mask=None,
                  lifted: bool = True, **kwargs) -> Pytree:
    """Batch tumbling-window aggregation through the execution planner.

    Window id == segment id: the whole stream folds in ONE keyed fold on
    whatever tier the planner picks, returning a ``(num_windows, ...)``
    table.  Events outside ``[t0, t0 + num_windows*width)`` are masked to
    the identity (the planner's ``valid_mask`` ragged path), composing with
    any caller-provided mask.  Extra ``kwargs`` (``mesh_axes=``,
    ``layout=``, ...) pass straight through to
    :func:`repro.core.plan.execute_fold`.
    """
    ids = tumbling_ids(timestamps, width=width, t0=t0)
    in_range = (ids >= 0) & (ids < num_windows)
    mask = (in_range if valid_mask is None
            else in_range & jnp.asarray(valid_mask, jnp.bool_))
    ids = jnp.clip(ids, 0, num_windows - 1)
    return execute_fold(m, values, segment_ids=ids,
                        num_segments=num_windows, valid_mask=mask,
                        lifted=lifted, **kwargs)


# ---------------------------------------------------------------------------
# sessionization — session id == segment id
# ---------------------------------------------------------------------------

def sessionize(user_ids, timestamps, *, gap: float) -> Tuple[np.ndarray, int]:
    """Split a time-ordered per-user event stream into sessions.

    A user's event starts a NEW session when it is their first event or
    arrives more than ``gap`` after their previous one.  Returns
    ``(session_ids, num_sessions)``: int32 ids dense in order of session
    birth — directly usable as the ``segment_ids`` of a planner keyed fold
    (:func:`session_fold`).  Host-side by construction: session assignment
    is inherently serial per user, while everything downstream of the ids
    is a data-parallel fold.
    """
    users = np.asarray(user_ids)
    ts = np.asarray(timestamps, np.float64)
    if users.ndim != 1 or users.shape != ts.shape:
        raise ValueError(
            f"user_ids and timestamps must be matching 1-D arrays, got "
            f"{users.shape} vs {ts.shape}")
    if ts.size > 1 and np.any(np.diff(ts) < 0):
        raise ValueError("timestamps must be non-decreasing (time-ordered "
                         "stream); sort events before sessionizing")
    out = np.empty(users.shape, np.int32)
    last_t: Dict[Any, float] = {}
    current: Dict[Any, int] = {}
    n = 0
    for i, (u, t) in enumerate(zip(users.tolist(), ts.tolist())):
        if u not in last_t or t - last_t[u] > gap:
            current[u] = n
            n += 1
        last_t[u] = t
        out[i] = current[u]
    return out, n


def session_fold(m: Monoid, values: Pytree, session_ids, num_sessions: int, *,
                 valid_mask=None, lifted: bool = True, **kwargs) -> Pytree:
    """Per-session aggregation: ONE planner-lowered keyed fold.

    ``session_ids`` come from :func:`sessionize`; the result is a
    ``(num_sessions, ...)`` table.  Cross-host, each host folds its local
    shard then merges tables with ``data.stats.sync_stats`` — exact,
    because a session table is itself a monoid value under the element-wise
    combine.
    """
    return execute_fold(m, values,
                        segment_ids=jnp.asarray(session_ids, jnp.int32),
                        num_segments=num_sessions, valid_mask=valid_mask,
                        lifted=lifted, **kwargs)


# ---------------------------------------------------------------------------
# the serving consumer — live per-user windows over engine stream events
# ---------------------------------------------------------------------------

class WindowedMetrics:
    """Per-user serving metrics as monoid windows (an engine consumer).

    Subscribe to a :class:`repro.runtime.engine.ContinuousEngine`::

        metrics = WindowedMetrics(window=32, half_life_s=60.0)
        engine = ContinuousEngine(backend, config,
                                  consumers=[metrics.observe])

    Per stream event:

    * ``token`` events fold ``(1, t)`` into the user's **decayed token
      rate** (``monoids.decayed_sum``) and into a fleet-wide
      :class:`TumblingWindow` token counter;
    * ``done`` events push ``(latency, ttft, tokens)`` into the user's
      **sliding window** of the last ``window`` completed requests (the
      mean-pair monoid — one two-stacks window carries all three means);
    * ``cache`` events (prefix-cache admissions) fold
      ``(hit_tokens, prompt_tokens, bytes_saved)`` into a fleet-wide
      tumbling counter — the live prefix hit rate is a ratio of two sums,
      so the windowed state stays a plain additive monoid.

    State is O(window) per user and O(1) for the fleet, independent of
    traffic volume — the streaming half of the Summingbird property.
    """

    def __init__(self, *, window: int = 32, half_life_s: float = 60.0,
                 tumble_s: float = 1.0):
        self.window = int(window)
        self.half_life_s = float(half_life_s)
        self._rate_m = monoids.decayed_sum(half_life_s)
        self._per_user: Dict[Any, SlidingWindow] = {}
        self._rate: Dict[Any, Tuple] = {}
        self._fleet = TumblingWindow(monoids.sum_, tumble_s,
                                     example=jnp.zeros((), jnp.float32))
        # (hit_tokens, prompt_tokens, bytes_saved) per tumble — one
        # vector-valued sum carries all three prefix-cache counters
        self._prefix = TumblingWindow(monoids.sum_, tumble_s,
                                      example=jnp.zeros((3,), jnp.float32))
        self.closed_fleet_windows: List[WindowResult] = []
        self.closed_prefix_windows: List[WindowResult] = []
        self.events = 0

    # -- the consumer entry point -------------------------------------------
    def observe(self, event) -> None:
        """Fold one engine ``StreamEvent`` in (duck-typed: ``kind``,
        ``user``, ``time_s``, and ``result`` for done events)."""
        self.events += 1
        if event.kind == "token":
            v = (jnp.ones((), jnp.float32),
                 jnp.asarray(event.time_s, jnp.float32))
            st = self._rate.get(event.user)
            self._rate[event.user] = (v if st is None
                                      else self._rate_m.combine(st, v))
            self.closed_fleet_windows.extend(
                self._fleet.push(jnp.ones((), jnp.float32), event.time_s))
        elif event.kind == "done":
            r = event.result
            w = self._per_user.get(event.user)
            if w is None:
                w = self._per_user[event.user] = SlidingWindow(
                    monoids.mean, self.window)
            w.push((jnp.asarray([r.latency_s, r.ttft_s,
                                 float(len(r.tokens))], jnp.float32),
                    jnp.ones((), jnp.int32)))
        elif event.kind == "cache":
            v = jnp.asarray([event.hit_tokens, event.prompt_tokens,
                             event.bytes_saved], jnp.float32)
            self.closed_prefix_windows.extend(
                self._prefix.push(v, event.time_s))

    # -- queries ------------------------------------------------------------
    def users(self) -> List[Any]:
        return sorted(set(self._per_user) | set(self._rate))

    def user_window(self, user) -> Dict[str, float]:
        """Windowed means over the user's last ``window`` requests."""
        w = self._per_user.get(user)
        if w is None or len(w) == 0:
            return {"requests": 0, "latency_s": 0.0, "ttft_s": 0.0,
                    "tokens": 0.0}
        mean = np.asarray(w.extract())
        return {"requests": len(w), "latency_s": float(mean[0]),
                "ttft_s": float(mean[1]), "tokens": float(mean[2])}

    def user_token_rate(self, user, now: float) -> float:
        """Decayed token count for ``user`` re-anchored to ``now``."""
        st = self._rate.get(user)
        if st is None:
            return 0.0
        return float(monoids.decayed_value(st, now, self.half_life_s))

    def fleet_tokens(self) -> float:
        """Total tokens across closed fleet windows plus the open one."""
        closed = sum(float(np.asarray(r.value))
                     for r in self.closed_fleet_windows)
        open_ = sum(float(np.asarray(r.value)) for r in self._fleet.flush())
        return closed + open_

    def fleet_prefix(self) -> Dict[str, float]:
        """Fleet prefix-cache counters across closed windows plus the open
        one: hit/prompt token totals, bytes saved, and the hit rate."""
        total = np.zeros((3,), np.float64)
        for r in self.closed_prefix_windows:
            total += np.asarray(r.value, np.float64)
        for r in self._prefix.flush():
            total += np.asarray(r.value, np.float64)
        return {"hit_tokens": float(total[0]),
                "prompt_tokens": float(total[1]),
                "bytes_saved": float(total[2]),
                "hit_rate": float(total[0] / max(total[1], 1.0))}

    def summary(self, now: float) -> Dict[Any, Dict[str, float]]:
        """Per-user snapshot: windowed means + decayed token rate."""
        out = {}
        for u in self.users():
            row = self.user_window(u)
            row["token_rate"] = self.user_token_rate(u, now)
            out[u] = row
        return out
