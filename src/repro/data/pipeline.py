"""Deterministic, host-sharded, resumable data pipeline.

Design (DESIGN.md §3):

* **Stateless generation** — batch ``i`` is a pure function of
  ``(seed, step=i, host_id)``; resuming from a checkpoint at step k needs no
  iterator state, only k. This is the data-side half of the monoid-restart
  guarantee (the aggregate of steps [0, k) combines with [k, n)).
* **Host sharding** — each host draws only its slice of the global batch
  (``host_id / num_hosts``), matching the jit in_shardings batch layout.
* **Synthetic corpus** — Zipf-distributed tokens with document structure
  (EOS-terminated docs, geometric lengths), packed to fixed seq_len. A stub
  for a real tokenized corpus; the interface (``__call__(step) -> batch``) is
  what the trainer depends on.
* **Ragged batches** — with ``ragged=True`` a row ends at its last complete
  (EOS-terminated) document; the tail is padding carried in a ``valid_mask``
  instead of being filled with a truncated document.  Every consumer folds
  through the planner's ``valid_mask=`` path (:func:`packed_stats`,
  ``data/stats.py``), so nothing downstream re-materializes a rectangle of
  real tokens.
* **Prefetch** — a depth-bounded background thread (double buffering).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from ..core import monoids
from ..core.plan import execute_fold


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 256
    eos_id: int = 0
    pad_id: int = 0
    ragged: bool = False   # emit valid_mask; keep only whole packed docs


class SyntheticCorpus:
    """batch(step) -> {tokens, labels} for this host's shard, deterministically."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, num_hosts: int = 1,
                 context_shape: Optional[tuple] = None,
                 context_dtype=jnp.bfloat16):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self.context_shape = context_shape
        self.context_dtype = context_dtype
        # Zipf over a fixed vocab via inverse-CDF on precomputed weights
        ranks = np.arange(1, cfg.vocab_size, dtype=np.float64)  # id 0 = EOS
        w = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(w) / w.sum()

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id]))

    def __call__(self, step: int) -> Dict[str, Any]:
        cfg = self.cfg
        rng = self._rng(step)
        B, S = self.local_batch, cfg.seq_len
        u = rng.random((B, S))
        toks = (np.searchsorted(self._cdf, u) + 1).astype(np.int32)
        # document structure: EOS with prob 1/mean_doc_len (geometric docs)
        eos_mask = rng.random((B, S)) < (1.0 / cfg.mean_doc_len)
        toks = np.where(eos_mask, cfg.eos_id, toks)
        batch: Dict[str, Any] = {}
        if cfg.ragged:
            # keep only whole documents: a row ends at its LAST EOS and the
            # tail (an incomplete doc) becomes padding under valid_mask —
            # consumers fold through the planner's mask path instead of this
            # pipeline inventing a truncated document to fill the rectangle
            is_eos = toks == cfg.eos_id
            has = is_eos.any(axis=1)
            last = np.where(has, (S - 1) - np.argmax(is_eos[:, ::-1], axis=1),
                            S - 1)   # no EOS: the whole row is one open doc
            valid = np.arange(S)[None, :] <= last[:, None]
            toks = np.where(valid, toks, cfg.pad_id)
            batch["valid_mask"] = jnp.asarray(valid)
        labels = np.concatenate([toks[:, 1:], np.full((B, 1), -1, np.int32)],
                                axis=1)
        if cfg.ragged:
            # no loss on predicting padding
            next_valid = np.concatenate(
                [valid[:, 1:], np.zeros((B, 1), bool)], axis=1)
            labels = np.where(next_valid, labels, -1)
        batch["tokens"] = jnp.asarray(toks)
        batch["labels"] = jnp.asarray(labels)
        if self.context_shape is not None:
            ctx = rng.standard_normal((B,) + tuple(self.context_shape),
                                      dtype=np.float32)
            batch["context"] = jnp.asarray(ctx, self.context_dtype)
        return batch


def packed_stats(tokens: jnp.ndarray, valid_mask: jnp.ndarray, *,
                 eos_id: int = 0) -> Dict[str, jnp.ndarray]:
    """Per-row packed-sequence stats as ONE masked keyed fold.

    tokens/valid_mask: (B, S).  Returns ``{"tokens": (B,), "docs": (B,)}`` —
    real-token count and completed-document (EOS) count per row.  Both
    columns ride a single planner-lowered keyed fold (segment id = row,
    ``valid_mask`` = the flattened padding mask): the ragged batch is never
    densified, padding rows fold the identity.
    """
    B, S = tokens.shape
    flat = tokens.reshape(-1)
    rows = jnp.stack([jnp.ones_like(flat, jnp.float32),
                      (flat == eos_id).astype(jnp.float32)], axis=-1)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), S)
    out = execute_fold(monoids.sum_, rows, segment_ids=seg, num_segments=B,
                       valid_mask=jnp.asarray(valid_mask,
                                              jnp.bool_).reshape(-1))
    return {"tokens": out[:, 0].astype(jnp.int32),
            "docs": out[:, 1].astype(jnp.int32)}


class Prefetcher:
    """Depth-bounded background prefetch over ``source(step)``.

    Exactly-once per step; ``close()`` joins the thread. Resumable: pass the
    restart step to the constructor.
    """

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 num_steps: Optional[int] = None):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._steps = range(start_step, num_steps if num_steps is not None
                            else (1 << 62))
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for step in self._steps:
            if self._stop.is_set():
                return
            batch = self.source(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
        self._q.put(None)

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
