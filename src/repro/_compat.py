"""JAX version compatibility shims.

The codebase is written against the modern JAX API surface
(``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.lax.axis_size``).  Older jaxlibs (the pinned CI
toolchain ships 0.4.x) expose the same functionality under different names:
``jax.experimental.shard_map.shard_map(check_rep=...)`` instead of
``jax.shard_map(check_vma=...)`` and no axis-type machinery at all (every
mesh axis behaves as ``Auto``).

:func:`install` forward-ports those names onto the ``jax`` module, so the
rest of the codebase — and the test suite's subprocess snippets — can use
one spelling everywhere.  On a new-enough JAX this is a no-op.  It runs once
at ``import repro`` and is idempotent.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def _wrap_check_vma(_sm):
    """Adapt a shard_map whose knob is still called ``check_rep``."""

    @functools.wraps(_sm)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        kw.setdefault("check_rep", check_vma)
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    return shard_map


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (Auto is the 0.4.x behaviour)."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _make_mesh_compat(real_make_mesh):
    @functools.wraps(real_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        # 0.4.x meshes are implicitly Auto on every axis; Explicit sharding
        # does not exist there, so the hint is validated and dropped.
        del axis_types
        return real_make_mesh(axis_shapes, axis_names, **kw)

    return make_mesh


def _axis_size(axis_name):
    """``jax.lax.axis_size``: psum of the unit is constant-folded to the
    (static, Python int) size of the named axis."""
    return jax.lax.psum(1, axis_name)


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _sm
        jax.shard_map = _wrap_check_vma(_sm)
    elif "check_vma" not in inspect.signature(jax.shard_map).parameters:
        # 0.5.x-0.6.0: top-level shard_map exists but the knob is check_rep
        jax.shard_map = _wrap_check_vma(jax.shard_map)
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
        jax.make_mesh = _make_mesh_compat(jax.make_mesh)
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size


install()
