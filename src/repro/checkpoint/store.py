"""Sharded, atomic, async checkpointing with monoid-merge resume.

Layout::

    <dir>/step_00000042/
        manifest.json        # tree structure, dtypes, shapes, monoid tags
        arrays/<n>.bin       # raw little-endian bytes per leaf
    <dir>/LATEST             # atomic pointer (text file, os.replace'd)

Properties:

* **Atomic** — a step directory is staged under ``.tmp-...`` and
  ``os.replace``d into place; LATEST is updated last. A crash mid-save never
  corrupts the previous checkpoint.
* **Async** — ``save_async`` snapshots device arrays to host (blocking only
  on device->host copy), then writes in a background thread; ``wait()``
  joins. At 1000-node scale each host writes only its addressable shards —
  here the single process writes everything, but the layout keys every leaf
  by (path, shard_index) so per-host sharding is a parameter, not a rewrite.
* **Monoid-merge resume** (the paper's point applied to fault tolerance):
  accumulators (metrics, data-pipeline sketches) are saved as monoid values
  with their monoid name in the manifest. On restore, training resumes at
  step k and the accumulator of steps [0,k) COMBINES with the new partial
  aggregate — associativity makes restart exact (tested in
  tests/test_checkpoint.py::test_restart_exactness).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_DTYPES = {
    "bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16,
    "int32": jnp.int32, "int64": jnp.int64, "uint32": jnp.uint32,
    "uint8": jnp.uint8, "int8": jnp.int8, "bool": jnp.bool_,
    "float64": jnp.float64, "uint16": jnp.uint16,
}


def _to_host(tree: Pytree) -> List[Tuple[str, np.ndarray]]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        out.append((jax.tree_util.keystr(path), np.asarray(leaf)))
    return out


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


class CheckpointStore:
    def __init__(self, base_dir: str, *, keep: int = 3):
        self.base = base_dir
        self.keep = keep
        os.makedirs(base_dir, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Pytree, *,
             aggregates: Optional[Dict[str, Tuple[str, Pytree]]] = None) -> str:
        """Blocking save. ``aggregates`` maps name -> (monoid_name, value)."""
        host = _to_host(tree)
        agg_host = {k: (mn, _to_host(v)) for k, (mn, v) in (aggregates or {}).items()}
        return self._write(step, host, agg_host)

    def save_async(self, step: int, tree: Pytree, *,
                   aggregates: Optional[Dict[str, Tuple[str, Pytree]]] = None) -> Future:
        """Device->host copy now; disk write in the background."""
        self.wait()
        host = _to_host(tree)
        agg_host = {k: (mn, _to_host(v)) for k, (mn, v) in (aggregates or {}).items()}
        self._pending = self._pool.submit(self._write, step, host, agg_host)
        return self._pending

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host, agg_host) -> str:
        final = _step_dir(self.base, step)
        tmp = os.path.join(self.base, f".tmp-{step}-{os.getpid()}-{time.monotonic_ns()}")
        arrays = os.path.join(tmp, "arrays")
        os.makedirs(arrays, exist_ok=True)
        manifest = {"step": step, "leaves": [], "aggregates": {}}
        idx = 0

        def dump(entries, into: List):
            nonlocal idx
            for key, arr in entries:
                fname = f"{idx}.bin"
                with open(os.path.join(arrays, fname), "wb") as f:
                    f.write(np.ascontiguousarray(arr).tobytes())
                into.append({"key": key, "file": fname, "dtype": str(arr.dtype),
                             "shape": list(arr.shape)})
                idx += 1

        dump(host, manifest["leaves"])
        for name, (mname, entries) in agg_host.items():
            manifest["aggregates"][name] = {"monoid": mname, "leaves": []}
            dump(entries, manifest["aggregates"][name]["leaves"])
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._update_latest(step)
        self._gc()
        return final

    def _update_latest(self, step: int) -> None:
        tmp = os.path.join(self.base, ".LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
        os.replace(tmp, os.path.join(self.base, "LATEST"))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.base):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.base, "LATEST")
        if not os.path.exists(p):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(p) as f:
            return int(f.read().strip())

    def _read_leaves(self, d: str, entries: List[dict]) -> List[np.ndarray]:
        out = []
        for e in entries:
            with open(os.path.join(d, "arrays", e["file"]), "rb") as f:
                buf = f.read()
            dt = _DTYPES.get(e["dtype"])
            arr = np.frombuffer(buf, dtype=np.dtype(dt) if e["dtype"] != "bfloat16"
                                else np.uint16)
            if e["dtype"] == "bfloat16":
                arr = jnp.asarray(arr.reshape(e["shape"]).view(jnp.bfloat16.dtype))
            else:
                arr = arr.reshape(e["shape"])
            out.append(arr)
        return out

    def restore(self, like: Pytree, *, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Tuple[int, Pytree]:
        """Restore into the structure of ``like`` (values ignored).

        ``shardings``: optional NamedSharding pytree — arrays are placed
        sharded (this is also the elastic-remesh path: restoring onto a
        DIFFERENT mesh than the one that saved is just a different shardings
        tree, because the on-disk layout is mesh-agnostic full arrays).
        """
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = _step_dir(self.base, step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = self._read_leaves(d, manifest["leaves"])
        treedef = jax.tree_util.tree_structure(like)
        assert treedef.num_leaves == len(leaves), (
            f"checkpoint has {len(leaves)} leaves, expected {treedef.num_leaves}")
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_leaves(shardings)
            leaves = [jax.device_put(jnp.asarray(a), s)
                      for a, s in zip(leaves, shard_leaves)]
        else:
            leaves = [jnp.asarray(a) for a in leaves]
        return step, jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_aggregate(self, name: str, like: Pytree, *,
                          step: Optional[int] = None) -> Optional[Pytree]:
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = _step_dir(self.base, step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        agg = manifest["aggregates"].get(name)
        if agg is None:
            return None
        leaves = self._read_leaves(d, agg["leaves"])
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(a) for a in leaves])
