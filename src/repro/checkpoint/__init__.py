"""repro.checkpoint — atomic, async, mesh-agnostic checkpoints with
monoid-merge resume."""
from .store import CheckpointStore
