"""Two-level (MaxText-style) logical sharding.

Models annotate parameters and activations with *logical* axis names
(``"batch"``, ``"embed"``, ``"mlp"``, ...); this module owns the single
table mapping logical names to *mesh* axes.  The split keeps every model
file mesh-agnostic: retargeting the same program from a host mesh to a
two-pod production mesh is a rule-table change, not a model change.

Mesh axes (see ``launch/mesh.py``):

  ``data``   fast-ICI data parallelism
  ``model``  fast-ICI tensor / expert / sequence parallelism
  ``pod``    the slow DCN axis between pods — data-parallel; monoid
             aggregation (gradients, metrics) crosses it exactly once per
             step, pre-combined (see ``dist/collectives.py``)

A *rule table* maps each logical name to one mesh axis, a tuple of mesh
axes, or ``None`` (replicated).  Two tables ship by default: TRAIN_RULES
(batch over ``pod`` x ``data``; features over ``model``) and SERVE_RULES
(batch over ``data`` only — serving stays inside one pod).

Divisibility and duplicate mesh axes are resolved structurally in
:func:`spec_for`: a mesh axis that does not divide the dimension (smoke
configs, batch=1 decode) or that an earlier dimension already consumed is
dropped rather than erroring, so one rule table serves every (arch x shape)
cell.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any
Rules = Dict[str, Any]          # logical name -> mesh axis | tuple | None


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

TRAIN_RULES: Rules = {
    # -- data dimensions
    "batch": ("pod", "data"),     # DP across pods (DCN) and within (ICI)
    "seq": None,                  # override seq="model" for sequence parallel
    "kv_seq": None,
    # -- parameter / activation feature dimensions
    "embed": None,                # residual stream replicated over 'model'
    "vocab": "model",
    "mlp": "model",
    "d_inner": "model",           # SSM/xLSTM inner dim (the 'mlp' of those blocks)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "expert": "model",            # expert parallelism shares the 'model' axis
    "q_lora": None,
    "kv_lora": None,
    "d_state": None,
    "layers": None,               # stacked scan (period) dimension
}

# Serving stays within one pod; otherwise the same two-level scheme.
SERVE_RULES: Rules = dict(TRAIN_RULES, batch=("data",))


def _axes_tuple(rule: Any) -> Tuple[str, ...]:
    """Normalize a rule value (str | tuple | None) to a tuple of mesh axes."""
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def trim_rules(rules: Rules, mesh: Mesh) -> Rules:
    """Drop mesh axes the current mesh doesn't have (e.g. 'pod' on 1 pod)."""
    out = {}
    for k, v in rules.items():
        axes = tuple(a for a in _axes_tuple(v) if a in mesh.shape)
        out[k] = axes if len(axes) > 1 else (axes[0] if axes else None)
    return out


# ---------------------------------------------------------------------------
# logical names -> PartitionSpec
# ---------------------------------------------------------------------------

def spec_for(names: Sequence[Optional[str]], rules: Rules, mesh: Mesh, *,
             shape: Optional[Tuple[int, ...]] = None) -> P:
    """PartitionSpec for one tensor's logical names under a rule table.

    Per dimension, mesh axes are kept left-to-right subject to:
      * the axis exists in ``mesh``;
      * no earlier dimension already used it (a mesh axis may appear at most
        once in a PartitionSpec — first logical dimension wins);
      * if ``shape`` is given, the kept axes' product divides the dimension
        (smoke configs / batch-1 decode fall back toward replication).

    ``names`` may be shorter than the tensor rank (PartitionSpec semantics:
    unnamed trailing dimensions are replicated).
    """
    names = tuple(names)
    if shape is not None:
        assert len(names) <= len(shape), (names, shape)
    used: set = set()
    entries = []
    for i, name in enumerate(names):
        kept, prod = [], 1
        for a in _axes_tuple(rules.get(name)) if name is not None else ():
            if a not in mesh.shape or a in used:
                continue
            size = mesh.shape[a]
            if shape is not None and shape[i] % (prod * size) != 0:
                continue
            kept.append(a)
            used.add(a)
            prod *= size
        entries.append(tuple(kept) if len(kept) > 1 else
                       (kept[0] if kept else None))
    while entries and entries[-1] is None:   # trailing Nones are noise
        entries.pop()
    return P(*entries)


def param_shardings(shapes: Pytree, axes: Pytree, mesh: Mesh,
                    rules: Rules) -> Pytree:
    """NamedSharding pytree for a parameter tree.

    ``shapes`` is the ShapeDtypeStruct tree from ``param_shapes``; ``axes``
    the parallel logical-axes tree from ``param_axes`` (tuple-of-names
    leaves, e.g. ``("layers", "expert", "embed", "mlp")``).
    """
    return jax.tree_util.tree_map(
        lambda s, ax: NamedSharding(
            mesh, spec_for(tuple(ax), rules, mesh, shape=s.shape)),
        shapes, axes)


# ---------------------------------------------------------------------------
# activation sharding constraints
# ---------------------------------------------------------------------------

# The active (mesh, rules) scope.  Models call act() unconditionally; outside
# a use_rules() scope (single-device smoke tests, plain jit) it is a no-op,
# so model code never needs a mesh to run.
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist_sharding_scope", default=None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Rules):
    """Activate (mesh, rules) for act() within this (trace-time) scope."""
    token = _ACTIVE.set((mesh, trim_rules(rules, mesh)))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def current_rules() -> Optional[Tuple[Mesh, Rules]]:
    """The active (mesh, rules), or None outside any use_rules scope."""
    return _ACTIVE.get()


def act(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Constrain an activation's sharding by logical names (no-op when no
    rules are active).  ``names`` must match ``x``'s rank."""
    scope = _ACTIVE.get()
    if scope is None:
        return x
    mesh, rules = scope
    spec = spec_for(tuple(names), rules, mesh, shape=tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
