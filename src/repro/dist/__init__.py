"""repro.dist — the distribution layer: logical-axis sharding + mesh-aware
monoid collectives.

Public API:
  TRAIN_RULES, SERVE_RULES, use_rules, act,
  spec_for, param_shardings, trim_rules           (sharding.py)
  ici_axes, dcn_axes, cross_mesh_allreduce,
  grad_sync, metrics_sync                         (collectives.py)
"""
from .sharding import (SERVE_RULES, TRAIN_RULES, act, current_rules,
                       param_shardings, spec_for, trim_rules, use_rules)
from .collectives import (cross_mesh_allreduce, dcn_axes, grad_sync, ici_axes,
                          metrics_sync)

__all__ = [
    "TRAIN_RULES", "SERVE_RULES", "use_rules", "current_rules", "act",
    "spec_for", "param_shardings", "trim_rules",
    "ici_axes", "dcn_axes", "cross_mesh_allreduce", "grad_sync",
    "metrics_sync",
]
