"""Mesh-aware monoid collectives: cross the DCN axis once, pre-combined.

``core.aggregation`` knows how to combine a monoid value across *named
axes*; this module knows which of a mesh's axes are fast (ICI, intra-pod)
and which are slow (DCN, inter-pod: the ``pod`` axis of
``launch/mesh.py``), and orders the reduction so the slow axis always sees
already-combined values — the paper's rack-aware combiner tree
(in-node combining of PAPERS.md's "In-node Combiners", one level up).

Everything here runs inside ``jax.shard_map``; mesh arguments are used only
to classify axes, never to launch collectives.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core.aggregation import (hierarchical_psum, monoid_allreduce,
                                monoid_hierarchical_allreduce,
                                monoid_reduce_scatter)
from ..core.monoid import Monoid, Pytree
from ..core import monoids

# Mesh axes wired over DCN rather than ICI.  One name today; a future
# multi-slice topology adds its axes here and every reduction below stays
# correct by associativity.
DCN_AXIS_NAMES: Tuple[str, ...] = ("pod",)


def split_axis_names(axes: Sequence[Any]) -> Tuple[Tuple[Any, ...], Tuple[Any, ...]]:
    """Classify axis names into (ici, dcn) — THE single definition of the
    fast/slow split, shared by these collectives and the execution planner
    (``core/plan.py``), so predicted tier ordering can never diverge from
    the executed one."""
    names = tuple(axes)
    ici = tuple(a for a in names if a not in DCN_AXIS_NAMES)
    dcn = tuple(a for a in names if a in DCN_AXIS_NAMES)
    return ici, dcn


def dcn_axes(mesh: Mesh, axes: Optional[Sequence[Any]] = None) -> Tuple[Any, ...]:
    """The slow (cross-pod) axes among ``axes`` (default: all mesh axes)."""
    names = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    return split_axis_names(names)[1]


def ici_axes(mesh: Mesh, axes: Optional[Sequence[Any]] = None) -> Tuple[Any, ...]:
    """The fast (intra-pod) axes among ``axes`` (default: all mesh axes)."""
    names = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    return split_axis_names(names)[0]


def cross_mesh_allreduce(m: Monoid, x: Pytree, mesh: Mesh,
                         axes: Optional[Sequence[Any]] = None) -> Pytree:
    """Combine a monoid value across mesh axes, fast axes first.

    Re-bracketing the combine as (ICI..., DCN...) is legal by associativity
    and means each pod sends exactly one pre-combined value over DCN instead
    of |ici| raw partials.
    """
    ordered = ici_axes(mesh, axes) + dcn_axes(mesh, axes)
    return monoid_hierarchical_allreduce(m, x, ordered)


def cross_axes_allreduce(m: Monoid, x: Pytree, axes: Sequence[Any]) -> Pytree:
    """Name-based :func:`cross_mesh_allreduce` — the collective tier of the
    execution planner (``core/plan.py``), callable inside shard_map where no
    Mesh object is at hand.  Axes are classified by name (DCN_AXIS_NAMES)
    and reduced fast-first."""
    ici, dcn = split_axis_names(axes)
    return monoid_hierarchical_allreduce(m, x, ici + dcn)


def combine_keyed_table(m: Monoid, table: Pytree, axis_name: Any, *,
                        algorithm: str = "allreduce") -> Pytree:
    """Combine a keyed (num_segments, ...) monoid table across ONE mesh axis
    with the shuffle algorithm the planner chose (``Plan.shuffle_algorithm``).

    'allreduce' — :func:`monoid_allreduce` (ring for the psum/pmax family,
    gather + on-device fold for generic monoids).  'reduce_scatter' — the
    MapReduce shuffle proper: each device combines its 1/P key shard
    (``monoid_reduce_scatter``), then the shards are all-gathered back so
    every device holds the full table; requires ``num_segments % P == 0``,
    which the planner guarantees before choosing it.  Must run inside
    shard_map over ``axis_name``.
    """
    if algorithm == "allreduce":
        return monoid_allreduce(m, table, axis_name)
    if algorithm != "reduce_scatter":
        raise ValueError(f"unknown shuffle algorithm {algorithm!r}")
    shard = monoid_reduce_scatter(m, table, axis_name)
    return jax.tree_util.tree_map(
        lambda v: jax.lax.all_gather(v, axis_name, axis=0, tiled=True), shard)


def grad_sync(grads: Pytree, mesh: Mesh,
              axes: Optional[Sequence[Any]] = None) -> Pytree:
    """Data-parallel gradient all-reduce for shard_map training loops.

    Inside a pod the sum is reduce-scattered over the fast axis; only the
    1/|ici| shard crosses DCN (``hierarchical_psum``).  With no DCN axis in
    the mesh this degrades to a plain hierarchical psum over ICI.
    """
    ici = ici_axes(mesh, axes)
    dcn = dcn_axes(mesh, axes)
    if not ici and not dcn:
        return grads
    if not ici:
        # pure cross-pod DP (no fast axis to scatter over): one flat psum
        return monoid_allreduce(monoids.grad_sum, grads, dcn)
    return hierarchical_psum(
        grads, ici_axis=ici if len(ici) > 1 else ici[0],
        dcn_axis=(dcn if len(dcn) > 1 else dcn[0]) if dcn else None)


def metrics_sync(metrics: Pytree, mesh: Mesh,
                 axes: Optional[Sequence[Any]] = None) -> Pytree:
    """Sum-monoid metric aggregation (loss_sum, tokens, expert_load, ...):
    one combine per axis, ICI first, so DCN carries a single scalar tree."""
    return cross_mesh_allreduce(monoids.sum_, metrics, mesh, axes)


# ---------------------------------------------------------------------------
# lossy DCN crossings — compressed representations on the slow wire
# ---------------------------------------------------------------------------

def _lossy_dcn_combine(spec, comp: Pytree, like: Pytree,
                       dcn: Sequence[Any]) -> Pytree:
    """Combine compressed gradient messages across the DCN axes; return dense.

    Each party contributes its compressed message (sparse {values, idx} or
    {q, scale}); the receiver sums the *messages* exactly — concatenate +
    scatter-add for sparse (the exact regime of
    :func:`repro.optim.compress.topk_sparse_monoid`: total entries fit the
    union capacity), dequantize-and-sum for int8 — so the only loss in the
    crossing is the compression itself, which error feedback recovers.
    What crosses the wire per party is ``spec.wire_bytes(like)``, not the
    dense bytes.
    """
    if spec.method == "int8":
        def leaf(c, g):
            q, s = c["q"], c["scale"]
            for ax in dcn:
                q = jax.lax.all_gather(q, ax, axis=0)
                s = jax.lax.all_gather(s, ax, axis=0)
            q = q.reshape((-1,) + g.shape)
            total = jnp.tensordot(s.reshape(-1), q.astype(jnp.float32),
                                  axes=([0], [0]))
            return total.astype(g.dtype)
        is_leaf = lambda x: isinstance(x, dict) and "q" in x  # noqa: E731
    else:
        def leaf(c, g):
            v, i = c["values"], c["idx"]
            for ax in dcn:
                v = jax.lax.all_gather(v, ax, axis=0)
                i = jax.lax.all_gather(i, ax, axis=0)
            flat = jnp.zeros((c["size"],), jnp.float32)
            flat = flat.at[i.reshape(-1)].add(v.reshape(-1))
            return flat.reshape(g.shape).astype(g.dtype)
        is_leaf = lambda x: isinstance(x, dict) and "values" in x  # noqa: E731
    return jax.tree_util.tree_map(leaf, comp, like, is_leaf=is_leaf)


def lossy_cross_axes(spec, grads: Pytree, axes: Sequence[Any], *,
                     ef: Pytree) -> Tuple[Pytree, Pytree]:
    """Gradient combine with a compressed DCN crossing: dense over ICI,
    ``spec``-compressed over DCN, error-feedback residual returned as the
    new fold state.

    With no DCN axis among ``axes`` compression buys nothing (ICI is the
    fast wire) and is skipped — the dense result and untouched ``ef`` come
    back, so callers can annotate unconditionally and only pay on meshes
    where the slow axis exists.
    """
    ici, dcn = split_axis_names(axes)
    if ici:
        grads = monoid_allreduce(monoids.grad_sum, grads, ici)
    if not dcn:
        return grads, ef
    comp, new_ef = spec.compress(grads, ef)
    return _lossy_dcn_combine(spec, comp, grads, dcn), new_ef


# ---------------------------------------------------------------------------
# async (double-buffered) microbatch fold — overlap the shuffle with compute
# ---------------------------------------------------------------------------

def async_microbatch_fold(m: Monoid, xs: Pytree, axes: Sequence[Any], *,
                          map_fn: Optional[Callable[[Pytree], Pytree]] = None,
                          lifted: bool = True, lossy=None,
                          ef: Optional[Pytree] = None,
                          ) -> Tuple[Pytree, Optional[Pytree]]:
    """Double-buffered microbatch fold: the DCN crossing of microbatch *i*'s
    ICI-combined partial is issued in the same scan body as microbatch
    *i+1*'s compute, so the compiler may overlap the slow crossing with
    useful work.  This is the execution behind ``layout='async'`` in
    :func:`repro.core.plan.execute_fold`.

    Schedule (n microbatches, n >= 1):

        compute(0)                                  # prologue
        for i in 1..n-1:  cross(i-1)  ||  compute(i)  # scan body: overlap
        cross(n-1); combine                          # exposed epilogue

    Only the epilogue crossing is structurally un-hideable; how much of the
    n-1 pipelined crossings is actually hidden is a platform property the
    calibration measures (``TierCoeff.overlap_frac`` — ~0 on CPU, where XLA
    serializes collectives against compute).

    Args:
      m: the fold monoid.  ``lossy`` requires an additive monoid (sum).
      xs: pytree stacked along a leading microbatch axis.
      axes: mesh axis names to combine across (classified ICI/DCN by name).
      map_fn: per-microbatch compute, applied before ``m.lift``.
      lossy: optional :class:`repro.optim.compress.LossySpec` — compress each
        partial's DCN crossing, error feedback carried in the scan carry
        (resumable fold state).
      ef: error-feedback state (required shape = partial's) when ``lossy``.

    Returns ``(total, new_ef)``; ``new_ef`` is ``ef`` passed through (or
    updated per crossing when ``lossy``).
    """
    if lossy is not None and m.name != "sum":
        raise ValueError(
            f"lossy= compression needs an additive fold; got monoid {m.name!r}")
    ici, dcn = split_axis_names(axes)

    def local(x):
        if map_fn is not None:
            v = m.lift(map_fn(x))
        elif not lifted:
            v = m.lift(x)
        else:
            v = x
        return monoid_hierarchical_allreduce(m, v, ici) if ici else v

    def cross(v, ef_c):
        if not dcn:
            return v, ef_c
        if lossy is None:
            return monoid_hierarchical_allreduce(m, v, dcn), ef_c
        comp, ef_c = lossy.compress(v, ef_c)
        return _lossy_dcn_combine(lossy, comp, v, dcn), ef_c

    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    first = local(jax.tree_util.tree_map(lambda x: x[0], xs))
    if n == 1:
        return cross(first, ef)

    def body(carry, x):
        acc, pending, ef_c = carry
        crossed, ef_c = cross(pending, ef_c)   # crossing of microbatch i ...
        cur = local(x)                         # ... issued with compute of i+1
        return (m.combine(acc, crossed), cur, ef_c), None

    rest = jax.tree_util.tree_map(lambda x: x[1:], xs)
    (acc, pending, ef), _ = jax.lax.scan(
        body, (m.identity_like(first), first, ef), rest)
    crossed, ef = cross(pending, ef)           # exposed epilogue crossing
    return m.combine(acc, crossed), ef
