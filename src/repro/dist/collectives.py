"""Mesh-aware monoid collectives: cross the DCN axis once, pre-combined.

``core.aggregation`` knows how to combine a monoid value across *named
axes*; this module knows which of a mesh's axes are fast (ICI, intra-pod)
and which are slow (DCN, inter-pod: the ``pod`` axis of
``launch/mesh.py``), and orders the reduction so the slow axis always sees
already-combined values — the paper's rack-aware combiner tree
(in-node combining of PAPERS.md's "In-node Combiners", one level up).

Everything here runs inside ``jax.shard_map``; mesh arguments are used only
to classify axes, never to launch collectives.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from ..core.aggregation import (hierarchical_psum, monoid_allreduce,
                                monoid_hierarchical_allreduce,
                                monoid_reduce_scatter)
from ..core.monoid import Monoid, Pytree
from ..core import monoids

# Mesh axes wired over DCN rather than ICI.  One name today; a future
# multi-slice topology adds its axes here and every reduction below stays
# correct by associativity.
DCN_AXIS_NAMES: Tuple[str, ...] = ("pod",)


def split_axis_names(axes: Sequence[Any]) -> Tuple[Tuple[Any, ...], Tuple[Any, ...]]:
    """Classify axis names into (ici, dcn) — THE single definition of the
    fast/slow split, shared by these collectives and the execution planner
    (``core/plan.py``), so predicted tier ordering can never diverge from
    the executed one."""
    names = tuple(axes)
    ici = tuple(a for a in names if a not in DCN_AXIS_NAMES)
    dcn = tuple(a for a in names if a in DCN_AXIS_NAMES)
    return ici, dcn


def dcn_axes(mesh: Mesh, axes: Optional[Sequence[Any]] = None) -> Tuple[Any, ...]:
    """The slow (cross-pod) axes among ``axes`` (default: all mesh axes)."""
    names = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    return split_axis_names(names)[1]


def ici_axes(mesh: Mesh, axes: Optional[Sequence[Any]] = None) -> Tuple[Any, ...]:
    """The fast (intra-pod) axes among ``axes`` (default: all mesh axes)."""
    names = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    return split_axis_names(names)[0]


def cross_mesh_allreduce(m: Monoid, x: Pytree, mesh: Mesh,
                         axes: Optional[Sequence[Any]] = None) -> Pytree:
    """Combine a monoid value across mesh axes, fast axes first.

    Re-bracketing the combine as (ICI..., DCN...) is legal by associativity
    and means each pod sends exactly one pre-combined value over DCN instead
    of |ici| raw partials.
    """
    ordered = ici_axes(mesh, axes) + dcn_axes(mesh, axes)
    return monoid_hierarchical_allreduce(m, x, ordered)


def cross_axes_allreduce(m: Monoid, x: Pytree, axes: Sequence[Any]) -> Pytree:
    """Name-based :func:`cross_mesh_allreduce` — the collective tier of the
    execution planner (``core/plan.py``), callable inside shard_map where no
    Mesh object is at hand.  Axes are classified by name (DCN_AXIS_NAMES)
    and reduced fast-first."""
    ici, dcn = split_axis_names(axes)
    return monoid_hierarchical_allreduce(m, x, ici + dcn)


def combine_keyed_table(m: Monoid, table: Pytree, axis_name: Any, *,
                        algorithm: str = "allreduce") -> Pytree:
    """Combine a keyed (num_segments, ...) monoid table across ONE mesh axis
    with the shuffle algorithm the planner chose (``Plan.shuffle_algorithm``).

    'allreduce' — :func:`monoid_allreduce` (ring for the psum/pmax family,
    gather + on-device fold for generic monoids).  'reduce_scatter' — the
    MapReduce shuffle proper: each device combines its 1/P key shard
    (``monoid_reduce_scatter``), then the shards are all-gathered back so
    every device holds the full table; requires ``num_segments % P == 0``,
    which the planner guarantees before choosing it.  Must run inside
    shard_map over ``axis_name``.
    """
    if algorithm == "allreduce":
        return monoid_allreduce(m, table, axis_name)
    if algorithm != "reduce_scatter":
        raise ValueError(f"unknown shuffle algorithm {algorithm!r}")
    shard = monoid_reduce_scatter(m, table, axis_name)
    return jax.tree_util.tree_map(
        lambda v: jax.lax.all_gather(v, axis_name, axis=0, tiled=True), shard)


def grad_sync(grads: Pytree, mesh: Mesh,
              axes: Optional[Sequence[Any]] = None) -> Pytree:
    """Data-parallel gradient all-reduce for shard_map training loops.

    Inside a pod the sum is reduce-scattered over the fast axis; only the
    1/|ici| shard crosses DCN (``hierarchical_psum``).  With no DCN axis in
    the mesh this degrades to a plain hierarchical psum over ICI.
    """
    ici = ici_axes(mesh, axes)
    dcn = dcn_axes(mesh, axes)
    if not ici and not dcn:
        return grads
    if not ici:
        # pure cross-pod DP (no fast axis to scatter over): one flat psum
        return monoid_allreduce(monoids.grad_sum, grads, dcn)
    return hierarchical_psum(
        grads, ici_axis=ici if len(ici) > 1 else ici[0],
        dcn_axis=(dcn if len(dcn) > 1 else dcn[0]) if dcn else None)


def metrics_sync(metrics: Pytree, mesh: Mesh,
                 axes: Optional[Sequence[Any]] = None) -> Pytree:
    """Sum-monoid metric aggregation (loss_sum, tokens, expert_load, ...):
    one combine per axis, ICI first, so DCN carries a single scalar tree."""
    return cross_mesh_allreduce(monoids.sum_, metrics, mesh, axes)
