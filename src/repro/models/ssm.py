"""SSM / recurrent blocks: Mamba (jamba), mLSTM + sLSTM (xlstm).

These are the paper's principle applied to sequence mixing: a linear
recurrence  h_t = a_t * h_{t-1} + b_t  is the composition of affine maps,
and affine maps form a monoid (``repro.core.monoids.affine_scan``).  That is
exactly why the selective scan parallelizes: ``lax.associative_scan`` is a
legal re-bracketing of the fold.  We use the *chunked* form everywhere —
``associative_scan`` inside a chunk (the combiner), a carried state across
chunks (in-mapper combining) — so live memory is O(chunk * d_inner * d_state)
instead of O(seq * d_inner * d_state).

Simplifications vs the exact papers (recorded in DESIGN.md §Arch-applicability):
* mLSTM: chunkwise linear-attention form with log-sigmoid forget decays;
  the running max-stabilizer m_t is folded into the per-chunk normalizer.
* sLSTM: exponential gating replaced by sigmoid gating (the block-diagonal
  recurrent structure and per-head state layout are kept).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common
from .common import ModelConfig, ParamBuilder, dense, rms_norm
from ..dist import sharding as shd


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — jamba's sequence mixer
# ---------------------------------------------------------------------------

def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba(pb: ParamBuilder, cfg: ModelConfig) -> None:
    D, DI, N, K = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    R = _dt_rank(cfg)
    pb.param("w_in", (D, 2 * DI), ("embed", "d_inner"), scale=D)     # x and gate z
    pb.param("conv_w", (K, DI), (None, "d_inner"), scale=K)
    pb.param("conv_b", (DI,), ("d_inner",), init="zeros")
    pb.param("w_bcdt", (DI, 2 * N + R), ("d_inner", None), scale=DI)
    pb.param("w_dt", (R, DI), (None, "d_inner"), scale=R)
    pb.param("dt_bias", (DI,), ("d_inner",), init="zeros")
    pb.param("A_log", (DI, N), ("d_inner", "d_state"), init="zeros")
    pb.param("D_skip", (DI,), ("d_inner",), init="ones")
    pb.param("w_out", (DI, D), ("d_inner", "embed"), scale=DI)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. x: (B,S,DI); w: (K,DI); state: (B,K-1,DI).

    Returns (y, new_state) where new_state is the last K-1 inputs."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                    # (B, S+K-1, DI)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    y = y + b
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y, new_state


def _mamba_scan_inputs(p: Dict, cfg: ModelConfig, xc: jnp.ndarray):
    """xc: post-conv activations (B,S,DI) -> discretized (abar, bbar_x, C)."""
    N, R = cfg.d_state, _dt_rank(cfg)
    bcdt = jnp.einsum("bsd,dr->bsr", xc, p["w_bcdt"].astype(xc.dtype))
    Bm, Cm, dt_in = bcdt[..., :N], bcdt[..., N:2 * N], bcdt[..., 2 * N:]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, p["w_dt"].astype(xc.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                     # (B,S,DI)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (DI,N)
    abar = jnp.exp(dt[..., None] * A)                           # (B,S,DI,N)
    bbar_x = (dt * xc.astype(jnp.float32))[..., None] * Bm[..., None, :].astype(jnp.float32)
    return abar, bbar_x, Cm.astype(jnp.float32)


def mamba_mix(p: Dict, cfg: ModelConfig, x: jnp.ndarray, *,
              chunk_size: int = 256) -> jnp.ndarray:
    """Full-sequence Mamba block (training/prefill), chunked parallel scan."""
    B, S, D = x.shape
    DI = cfg.d_inner
    xz = dense(x, p["w_in"])
    xi, z = xz[..., :DI], xz[..., DI:]
    xi = shd.act(xi, ("batch", "seq", "mlp"))
    xc, _ = _causal_conv(xi, p["conv_w"].astype(xi.dtype), p["conv_b"].astype(xi.dtype))
    xc = jax.nn.silu(xc)
    abar, bbar_x, Cm = _mamba_scan_inputs(p, cfg, xc)

    cs = min(chunk_size, S)
    while S % cs:
        cs //= 2
    n_chunks = S // cs

    def chunked(t):
        return t.reshape((B, n_chunks, cs) + t.shape[2:]).swapaxes(0, 1)

    abar_c, bbarx_c, C_c = chunked(abar), chunked(bbar_x), chunked(Cm)
    h0 = jnp.zeros((B, DI, cfg.d_state), jnp.float32)

    def chunk_step(h, inp):
        a, bx, c = inp                                          # (B,cs,DI,N)
        # prefix-compose the affine maps inside the chunk (the combiner)
        a_pref, bx_pref = jax.lax.associative_scan(
            lambda f, g: (g[0] * f[0], g[0] * f[1] + g[1]), (a, bx), axis=1)
        h_all = a_pref * h[:, None] + bx_pref                   # (B,cs,DI,N)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c)               # C read-out
        # stream the chunk output in the model dtype; only the carry stays
        # f32 (§Perf iter 4: scan ys buffers are (S, B, DI)-sized)
        ydt = jnp.float32 if common._F32_CHAINS else x.dtype
        return h_all[:, -1], y.astype(ydt)

    _, ys = jax.lax.scan(chunk_step, h0, (abar_c, bbarx_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, S, DI)
    if common._F32_CHAINS:
        y = y + p["D_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
        y = y.astype(x.dtype) * jax.nn.silu(z)
    else:
        y = y + (p["D_skip"].astype(x.dtype) * xc)
        y = y * jax.nn.silu(z)
    out = dense(y, p["w_out"])
    return shd.act(out, ("batch", "seq", "embed"))


def init_mamba_cache(cfg: ModelConfig, batch: int):
    return {
        "ssm_h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "ssm_conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), cfg.dtype),
    }


def mamba_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: O(1) state update. x: (B,1,D)."""
    DI = cfg.d_inner
    xz = dense(x, p["w_in"])
    xi, z = xz[..., :DI], xz[..., DI:]
    xc, conv_state = _causal_conv(xi, p["conv_w"].astype(xi.dtype),
                                  p["conv_b"].astype(xi.dtype), cache["ssm_conv"])
    xc = jax.nn.silu(xc)
    abar, bbar_x, Cm = _mamba_scan_inputs(p, cfg, xc)           # (B,1,DI,N)
    h = abar[:, 0] * cache["ssm_h"] + bbar_x[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
    y = y + p["D_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = dense(y, p["w_out"])
    return out, {"ssm_h": h, "ssm_conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM — matrix-memory LSTM (xlstm), chunkwise linear-attention form
# ---------------------------------------------------------------------------

def init_mlstm(pb: ParamBuilder, cfg: ModelConfig) -> None:
    D = cfg.d_model
    DI = int(cfg.mlstm_proj_factor * D)
    H = cfg.num_heads
    hd = DI // H
    pb.param("w_up", (D, 2 * DI), ("embed", "d_inner"), scale=D)  # x and gate
    pb.param("wq", (DI, H, hd), ("d_inner", "heads", "head_dim"), scale=DI)
    pb.param("wk", (DI, H, hd), ("d_inner", "heads", "head_dim"), scale=DI)
    pb.param("wv", (DI, H, hd), ("d_inner", "heads", "head_dim"), scale=DI)
    pb.param("w_if", (DI, 2 * H), ("d_inner", None), scale=DI)    # input/forget gates
    pb.param("b_if", (2 * H,), (None,), init="zeros")
    pb.param("ln_g", (DI,), ("d_inner",), init="ones")            # group-norm over heads
    pb.param("w_down", (DI, D), ("d_inner", "embed"), scale=DI)


def _mlstm_qkv(p, cfg, xi):
    H = cfg.num_heads
    q = jnp.einsum("bsd,dhk->bshk", xi, p["wq"].astype(xi.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xi, p["wk"].astype(xi.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xi, p["wv"].astype(xi.dtype))
    gates = jnp.einsum("bsd,dg->bsg", xi, p["w_if"].astype(xi.dtype)) + \
        p["b_if"].astype(xi.dtype)
    logi = jax.nn.log_sigmoid(gates[..., :H].astype(jnp.float32))   # (B,S,H)
    logf = jax.nn.log_sigmoid(gates[..., H:].astype(jnp.float32))
    hd = q.shape[-1]
    return q, k / math.sqrt(hd), v, logi, logf


def mlstm_mix(p: Dict, cfg: ModelConfig, x: jnp.ndarray, *,
              chunk_size: int = 128) -> jnp.ndarray:
    """Chunkwise mLSTM: intra-chunk masked matmul + cross-chunk (C, n) carry.

    Per head: C_t = f_t C_{t-1} + i_t k_t v_t^T ; n_t = f_t n_{t-1} + i_t k_t ;
    h_t = (q_t^T C_t) / max(|q_t^T n_t|, 1). The (C, n) pair under the decay
    recurrence is an affine-monoid value; chunking is the legal re-bracketing.
    """
    B, S, D = x.shape
    DI = int(cfg.mlstm_proj_factor * D)
    H = cfg.num_heads
    hd = DI // H
    up = dense(x, p["w_up"])
    xi, z = up[..., :DI], up[..., DI:]
    xi = shd.act(xi, ("batch", "seq", "mlp"))
    q, k, v, logi, logf = _mlstm_qkv(p, cfg, xi)

    cs = min(chunk_size, S)
    while S % cs:
        cs //= 2
    n_chunks = S // cs

    def chunked(t, axes=(0, 1)):
        return t.reshape((B, n_chunks, cs) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = chunked(q), chunked(k), chunked(v)
    lic, lfc = chunked(logi), chunked(logf)
    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)

    def chunk_step(carry, inp):
        C, n = carry
        qi, ki, vi, li, lf = inp                                # (B,cs,H,*), (B,cs,H)
        F = jnp.cumsum(lf, axis=1)                              # within-chunk decay
        # inter-chunk: h_inter = exp(F_t) q_t . C_prev
        qf = (qi.astype(jnp.float32) * jnp.exp(F)[..., None])
        h_inter = jnp.einsum("bshk,bhkv->bshv", qf, C)
        n_inter = jnp.einsum("bshk,bhk->bsh", qf, n)
        # intra-chunk: masked decayed scores
        dec = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]  # (B,t,s,H)
        keep = jnp.tril(jnp.ones((cs, cs), bool))[None, :, :, None]
        w = jnp.where(keep, jnp.exp(dec), 0.0)
        scores = jnp.einsum("bthk,bshk->btsh", qi.astype(jnp.float32),
                            ki.astype(jnp.float32)) * w
        h_intra = jnp.einsum("btsh,bshv->bthv", scores, vi.astype(jnp.float32))
        # normalizer read: q_t . n_t = sum_s decay * (q_t . k_s) = sum_s scores
        h = h_inter + h_intra
        nq = n_inter + scores.sum(axis=2)
        h = h / jnp.maximum(jnp.abs(nq), 1.0)[..., None]
        # update carry to end of chunk
        Fe = F[:, -1]                                           # (B,H)
        decay_e = jnp.exp(Fe[:, None] - F + li)                 # (B,cs,H)
        C = C * jnp.exp(Fe)[..., None, None] + jnp.einsum(
            "bshk,bshv->bhkv", ki.astype(jnp.float32) * decay_e[..., None],
            vi.astype(jnp.float32))
        n = n * jnp.exp(Fe)[..., None] + jnp.einsum(
            "bsh,bshk->bhk", decay_e, ki.astype(jnp.float32))
        # stream chunk outputs in the model dtype (carry stays f32)
        ydt = jnp.float32 if common._F32_CHAINS else x.dtype
        return (C, n), h.astype(ydt)

    (_, _), hs = jax.lax.scan(chunk_step, (C0, n0), (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(0, 1).reshape(B, S, DI).astype(x.dtype)
    h = rms_norm(h, p["ln_g"], cfg.norm_eps)                    # (group) norm
    h = h * jax.nn.silu(z)
    out = dense(h, p["w_down"])
    return shd.act(out, ("batch", "seq", "embed"))


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    DI = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    hd = DI // H
    return {"ml_C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "ml_n": jnp.zeros((batch, H, hd), jnp.float32)}


def mlstm_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    B = x.shape[0]
    D = cfg.d_model
    DI = int(cfg.mlstm_proj_factor * D)
    up = dense(x, p["w_up"])
    xi, z = up[..., :DI], up[..., DI:]
    q, k, v, logi, logf = _mlstm_qkv(p, cfg, xi)                # (B,1,H,hd)
    f = jnp.exp(logf[:, 0])[..., None]                          # (B,H,1)
    i = jnp.exp(logi[:, 0])[..., None]
    kf, vf, qf = (t[:, 0].astype(jnp.float32) for t in (k, v, q))
    C = cache["ml_C"] * f[..., None] + i[..., None] * kf[..., :, None] * vf[..., None, :]
    n = cache["ml_n"] * f + i * kf
    h = jnp.einsum("bhk,bhkv->bhv", qf, C)
    nq = jnp.einsum("bhk,bhk->bh", qf, n)
    h = (h / jnp.maximum(jnp.abs(nq), 1.0)[..., None]).reshape(B, 1, DI).astype(x.dtype)
    h = rms_norm(h, p["ln_g"], cfg.norm_eps) * jax.nn.silu(z)
    return dense(h, p["w_down"]), {"ml_C": C, "ml_n": n}


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory LSTM with block-diagonal recurrence (xlstm)
# ---------------------------------------------------------------------------

def init_slstm(pb: ParamBuilder, cfg: ModelConfig) -> None:
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    pb.param("w_x", (D, 4 * D), ("embed", "d_inner"), scale=D)      # i,f,z,o from x
    pb.param("w_h", (H, hd, 4 * hd), ("heads", "head_dim", None), scale=hd)
    pb.param("b", (4 * D,), ("d_inner",), init="zeros")
    F = int(cfg.slstm_proj_factor * D)
    pb.param("w_up", (D, F), ("embed", "mlp"), scale=D)
    pb.param("w_down", (F, D), ("mlp", "embed"), scale=F)


def _slstm_cell(p, cfg, xg, h, c):
    """One recurrent step. xg: (B,4D) precomputed x-part; h,c: (B,H,hd)."""
    B = xg.shape[0]
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    rec = jnp.einsum("bhk,hkg->bhg", h, p["w_h"].astype(h.dtype))   # (B,H,4hd)
    g = xg.reshape(B, H, 4 * hd) + rec + p["b"].astype(xg.dtype).reshape(H, 4 * hd)
    i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    z = jnp.tanh(z)
    c = f * c + i * z
    h = o * jnp.tanh(c)
    return h, c


def slstm_mix(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Sequential sLSTM over the sequence + small gated-MLP projection.

    §Perf iter 4b note: a chunk-unrolled variant (scan over blocks of 16
    steps) was hypothesized to amortize the backward's per-step w_h^T /
    gradient-accumulate traffic; measurement REFUTED it (memory term +2%,
    compile time 3x) — the per-step gradient adds are sequential and do not
    CSE. Kept as the plain scan.
    """
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    xg = dense(x, p["w_x"])                                     # (B,S,4D)
    h0 = jnp.zeros((B, H, hd), jnp.float32)
    c0 = jnp.zeros((B, H, hd), jnp.float32)
    ydt = jnp.float32 if common._F32_CHAINS else x.dtype

    def step(carry, xt):
        h, c = carry
        h, c = _slstm_cell(p, cfg, xt, h, c)
        return (h, c), h.astype(ydt)

    (_, _), hs = jax.lax.scan(step, (h0, c0), xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    y = dense(jax.nn.silu(dense(y, p["w_up"])), p["w_down"])
    return shd.act(y, ("batch", "seq", "embed"))


def init_slstm_cache(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    hd = cfg.d_model // H
    return {"sl_h": jnp.zeros((batch, H, hd), jnp.float32),
            "sl_c": jnp.zeros((batch, H, hd), jnp.float32)}


def slstm_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    B, _, D = x.shape
    xg = dense(x, p["w_x"])[:, 0]
    h, c = _slstm_cell(p, cfg, xg, cache["sl_h"], cache["sl_c"])
    y = h.reshape(B, 1, D).astype(x.dtype)
    y = dense(jax.nn.silu(dense(y, p["w_up"])), p["w_down"])
    return y, {"sl_h": h, "sl_c": c}
