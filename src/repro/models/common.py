"""Model substrate: config, parameter machinery, norms, rotary embeddings.

Parameters are plain nested dicts of jax arrays. Every parameter leaf is
created through :class:`ParamBuilder` which records a parallel pytree of
*logical axis names* (e.g. ``("embed", "mlp")``); the distribution layer
(`repro.dist.sharding`) maps logical names -> mesh axes per mode. This is the
MaxText-style two-level sharding scheme: models never mention mesh axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

# Block types that can appear in a layer pattern.
BLOCK_TYPES = ("attn", "local", "mamba", "mlstm", "slstm", "xattn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every architecture in the assigned pool.

    ``layer_pattern`` is the repeating period of block types; layer ``i`` has
    type ``layer_pattern[i % len(layer_pattern)]``.  ``ffn_pattern`` likewise
    gives the FFN type ('dense' | 'moe' | 'none') per pattern slot.
    ``prelude_dense_layers`` forces the first k layers to use dense FFN
    (DeepSeek-V2's first_k_dense_replace).
    """

    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # -- attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 4096       # for 'local' blocks
    layer_pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("dense",)
    prelude_dense_layers: int = 0
    # -- MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    norm_topk_prob: bool = True
    moe_capacity_factor: float = 1.25
    num_padded_experts: int = 0      # trailing experts masked out of routing
                                     # (qwen2-moe: 60 real + 4 pads for EP=16)
    # -- MLA (DeepSeek-V2)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # -- SSM (Mamba)
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    # -- xLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333
    # -- encoder-decoder (whisper): decoder uses the main fields
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper 30s @ 50Hz after conv stub
    # -- vision cross-attention (llama-3.2-vision)
    num_image_tokens: int = 0        # stubbed patch-embedding count
    # -- FFN flavour
    act_fn: str = "silu"             # silu | gelu
    gated_ffn: bool = True           # SwiGLU (llama-family) vs plain MLP (whisper)
    scale_embed: bool = False        # multiply embeddings by sqrt(d_model) (gemma)
    decoder_cross_attn: bool = False # every attn layer also cross-attends (whisper)
    # -- numerics / misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16        # activation/weight dtype
    # long-context capability: True for SSM/hybrid archs (O(1)/chunked state)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert len(self.layer_pattern) == len(self.ffn_pattern), (
            "layer_pattern and ffn_pattern must be slot-aligned")

    # -- layer program ----------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def remainder_slots(self) -> int:
        return self.num_layers % self.period

    def block_type(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % self.period]

    def ffn_type(self, layer_idx: int) -> str:
        if layer_idx < self.prelude_dense_layers:
            return "dense"
        return self.ffn_pattern[layer_idx % self.period]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def num_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D)."""
        from . import transformer  # local import to avoid cycle
        shapes = transformer.param_shapes(self)
        return sum(math.prod(s.shape) for s in jax.tree_util.tree_leaves(shapes))

    def num_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k routed experts)."""
        if self.num_experts == 0:
            return self.num_params()
        total = self.num_params()
        # each routed expert is 3 matrices of d_model x d_ff_expert
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.ffn_type(i) == "moe")
        inactive = (self.num_experts - self.moe_top_k) * per_expert * n_moe_layers
        return total - inactive


# ---------------------------------------------------------------------------
# parameter builder: records logical axes alongside shapes
# ---------------------------------------------------------------------------

class ParamBuilder:
    """Collects parameter leaves and their logical sharding axes.

    Usage::

        pb = ParamBuilder(key, dtype)
        w = pb.param("wq", (d, h, hd), ("embed", "heads", "head_dim"), scale=d)

    ``pb.axes`` mirrors the params dict with tuples of logical names.
    """

    def __init__(self, key: Optional[jax.Array], dtype=jnp.bfloat16, *,
                 abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def _next_key(self):
        if self.abstract:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              *, scale: Optional[float] = None, init: str = "normal") -> Any:
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            w = jax.ShapeDtypeStruct(shape, self.dtype)
        elif init == "zeros":
            w = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            w = jnp.ones(shape, self.dtype)
        else:
            fan_in = scale if scale is not None else (shape[0] if shape else 1)
            std = 1.0 / math.sqrt(max(fan_in, 1))
            w = (jax.random.normal(self._next_key(), shape, jnp.float32) * std).astype(self.dtype)
        self.params[name] = w
        self.axes[name] = axes
        return w

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype, abstract=self.abstract)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub


# ---------------------------------------------------------------------------
# norms / embeddings
# ---------------------------------------------------------------------------

# --- precision-chain policy (EXPERIMENTS.md §Perf, iteration 2) -------------
# f32_chains=True  : norms/rotary/projections upcast to f32 and cast back —
#                    the initial (baseline) implementation.
# f32_chains=False : f32 only where it buys accuracy (variance reductions,
#                    softmax logits, MXU internal accumulation); the big
#                    (B,S,D)-shaped elementwise chains — and therefore their
#                    backward cotangent chains — stay in bf16.
_F32_CHAINS = False


def set_f32_chains(value: bool) -> None:
    global _F32_CHAINS
    _F32_CHAINS = bool(value)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm: fp32 for the variance REDUCTION; elementwise multiplies in
    the input dtype unless the baseline f32-chain policy is active.

    Perf note (§Perf iter 2): upcasting the whole activation to f32 makes
    every residual-stream cotangent chain f32 — 2x HBM traffic on (B,S,D)
    tensors per layer."""
    if _F32_CHAINS:
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps)
        return (out * gamma.astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * gamma.astype(x.dtype)


def rotary_embed(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary position embedding.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    Rotates pairs (x[2i], x[2i+1]) — the HF 'half-split' convention.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, half)
    # sin/cos tables in f32 (cheap, (S, half)); the rotation multiplies stay
    # in x's dtype so fwd/bwd chains on (B,S,H,hd) are bf16 (§Perf iter 2)
    dt = jnp.float32 if _F32_CHAINS else x.dtype
    cos = jnp.cos(angles)[..., None, :].astype(dt)
    sin = jnp.sin(angles)[..., None, :].astype(dt)
    x1, x2 = x[..., :half].astype(dt), x[..., half:].astype(dt)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(embedding: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Token embedding lookup via one-hot matmul on the MXU when the vocab is
    sharded (gather over a sharded axis lowers to all-gather; one-hot matmul
    reduce-scatters instead), plain take otherwise. XLA SPMD handles `take`
    on sharded operands, so we keep `take` and let the partitioner choose."""
    return jnp.take(embedding, tokens, axis=0)


def dense(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x @ w. The MXU accumulates bf16 inputs in f32 internally and rounds
    once at the output; emitting bf16 directly (instead of
    preferred_element_type=f32 + convert) halves the dot's output traffic
    (§Perf iteration 2). Softmax logits keep explicit f32 (attention.py)."""
    if _F32_CHAINS:
        out = jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        out = jax.lax.dot_general(
            x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())))
    if b is not None:
        out = out + b.astype(out.dtype)
    return out
