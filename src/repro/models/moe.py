"""Mixture-of-Experts FFN with expert parallelism.

The MoE layer is the framework's clearest MapReduce instance (DESIGN.md §2):

    map     : the router assigns each token to top-k experts
    shuffle : tokens travel to expert-owning devices
    reduce  : expert outputs are combined per token, weighted by the gate —
              a weighted-Sum monoid; router load/drop statistics ride along
              as a piggybacked Sum-monoid tuple (one collective, not two).

Two executable strategies (mirroring the paper's naive-vs-combined framing):

* ``replicated`` (baseline) — activations are replicated across the expert
  axis; every expert shard computes the contributions of ITS experts for all
  local tokens and one ``psum`` combines. Wire cost: one psum of (T, D).
* ``a2a`` — GShard-style all_to_all dispatch: each device sends only the
  tokens routed to remote experts (capacity-bounded) and receives them back.
  Wire cost: 2 * T*k/P * D — the combiner-style reduction of shuffle bytes.

Both run inside ``shard_map`` over the expert ('model') axis and are
numerically identical up to capacity drops.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamBuilder, dense
from ..dist import sharding as shd


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_dense_ffn(pb: ParamBuilder, cfg: ModelConfig, d_ff: Optional[int] = None) -> None:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    if cfg.gated_ffn:
        pb.param("w_gate", (D, F), ("embed", "mlp"), scale=D)
    pb.param("w_up", (D, F), ("embed", "mlp"), scale=D)
    pb.param("w_down", (F, D), ("mlp", "embed"), scale=F)


def init_moe(pb: ParamBuilder, cfg: ModelConfig) -> None:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    pb.param("router", (D, E), ("embed", None), scale=D)
    pb.param("we_gate", (E, D, F), ("expert", "embed", "mlp"), scale=D)
    pb.param("we_up", (E, D, F), ("expert", "embed", "mlp"), scale=D)
    pb.param("we_down", (E, F, D), ("expert", "mlp", "embed"), scale=F)
    if cfg.num_shared_experts > 0:
        shared = pb.child("shared")
        init_dense_ffn(shared, cfg, d_ff=cfg.num_shared_experts * F)


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def _act(cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(x) if cfg.act_fn == "silu" else jax.nn.gelu(x)


def dense_ffn(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    up = dense(x, p["w_up"])
    if cfg.gated_ffn:
        up = _act(cfg, dense(x, p["w_gate"])) * up
    else:
        up = _act(cfg, up)
    up = shd.act(up, ("batch", "seq", "mlp"))
    out = dense(up, p["w_down"])
    return shd.act(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def route(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token -> (top-k expert ids, gate weights). x: (T, D) flattened tokens."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if cfg.num_padded_experts:
        pad = jnp.arange(cfg.num_experts) >= cfg.num_experts - cfg.num_padded_experts
        logits = jnp.where(pad, -1e30, logits)
    scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, cfg.moe_top_k)        # (T, k)
    if cfg.norm_topk_prob:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_e.astype(jnp.int32), top_w


def _expert_compute(cfg: ModelConfig, p: Dict, xbuf: jnp.ndarray,
                    group_sizes: jnp.ndarray, *, local_slice=None) -> jnp.ndarray:
    """Grouped SwiGLU over sorted token buffer via lax.ragged_dot.

    xbuf: (C, D) tokens sorted by expert; group_sizes: (E_local,).
    local_slice: optional (start, size) to slice the expert dim of weights
    (used inside shard_map where weights arrive already sliced)."""
    wg, wu, wd = p["we_gate"], p["we_up"], p["we_down"]
    if local_slice is not None:
        s, n = local_slice
        wg = jax.lax.dynamic_slice_in_dim(wg, s, n, 0)
        wu = jax.lax.dynamic_slice_in_dim(wu, s, n, 0)
        wd = jax.lax.dynamic_slice_in_dim(wd, s, n, 0)
    dt = xbuf.dtype
    h = jax.nn.silu(jax.lax.ragged_dot(xbuf, wg.astype(dt), group_sizes)) \
        * jax.lax.ragged_dot(xbuf, wu.astype(dt), group_sizes)
    return jax.lax.ragged_dot(h, wd.astype(dt), group_sizes)


# ---------------------------------------------------------------------------
# single-device reference (also the smoke-test path)
# ---------------------------------------------------------------------------

def moe_ffn_local(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """MoE forward on one device: sort-by-expert + ragged grouped matmul."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    T = xf.shape[0]
    k, E = cfg.moe_top_k, cfg.num_experts
    top_e, top_w = route(p, cfg, xf)                            # (T,k)
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e)                                 # sort by expert
    tok = order // k
    xbuf = xf[tok]                                              # (T*k, D)
    gs = jnp.bincount(flat_e, length=E)                         # group sizes
    out_buf = _expert_compute(cfg, p, xbuf, gs)                 # (T*k, D)
    w = top_w.reshape(-1)[order].astype(out_buf.dtype)          # gate weights
    out = jnp.zeros_like(xf).at[tok].add(out_buf * w[:, None])
    stats = {"expert_load": gs, "dropped": jnp.zeros((), jnp.int32)}
    if cfg.num_shared_experts > 0:
        out = out + dense_ffn(p["shared"], cfg, x).reshape(-1, D)
    return out.reshape(B, S, D), stats


# ---------------------------------------------------------------------------
# expert-parallel strategies (shard_map over the expert axis)
# ---------------------------------------------------------------------------

def _divisible_batch_axes(mesh, batch_axes, B: int):
    """Keep only mesh axes present AND dividing the batch dim (B=1 decode)."""
    kept, total = [], 1
    for a in batch_axes:
        if a in mesh.shape and B % (total * mesh.shape[a]) == 0:
            kept.append(a)
            total *= mesh.shape[a]
    return tuple(kept)


def _capacity(cfg: ModelConfig, T: int, P: int) -> int:
    """Per-device token-buffer capacity (multiple of 8 for lane alignment)."""
    c = int(math.ceil(T * cfg.moe_top_k * cfg.moe_capacity_factor / P))
    return max(8, ((c + 7) // 8) * 8)


def moe_ffn_replicated(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh,
                       *, axis_name: str = "model",
                       batch_axes: Tuple[str, ...] = ("pod", "data")
                       ) -> Tuple[jnp.ndarray, Dict]:
    """Baseline EP: tokens replicated over the expert axis; each shard
    computes only its experts' contributions; one psum combines (the
    weighted-Sum monoid across expert shards)."""
    B, S, D = x.shape
    E = cfg.num_experts
    P = mesh.shape[axis_name]
    assert E % P == 0, (E, P)
    E_local = E // P
    batch_axes = _divisible_batch_axes(mesh, batch_axes, B)
    Pspec = jax.sharding.PartitionSpec

    def body(xl, router, wg, wu, wd):
        pl = {"router": router, "we_gate": wg, "we_up": wu, "we_down": wd}
        Bl, Sl = xl.shape[0], xl.shape[1]
        xf = xl.reshape(-1, D)
        T = Bl * Sl
        C = _capacity(cfg, T, P)
        top_e, top_w = route(pl, cfg, xf)                       # identical on all shards
        e0 = jax.lax.axis_index(axis_name) * E_local
        flat_e = top_e.reshape(-1)
        local_e = flat_e - e0
        is_mine = (local_e >= 0) & (local_e < E_local)
        sort_key = jnp.where(is_mine, local_e, E_local)         # sentinel last
        order = jnp.argsort(sort_key)[:C]                       # capacity-bounded
        tok = order // cfg.moe_top_k
        xbuf = xf[tok]
        kept = is_mine[order]
        gs_full = jnp.bincount(jnp.where(is_mine, local_e, E_local), length=E_local + 1)
        taken = jnp.minimum(jnp.cumsum(gs_full[:E_local]), C)
        gs = jnp.diff(taken, prepend=0)
        gs = jnp.concatenate([gs, jnp.array([C], gs.dtype) - gs.sum()[None]])
        wd_pad = jnp.concatenate([wd, jnp.zeros_like(wd[:1])], 0)
        wg_pad = jnp.concatenate([wg, jnp.zeros_like(wg[:1])], 0)
        wu_pad = jnp.concatenate([wu, jnp.zeros_like(wu[:1])], 0)
        pl_pad = {"we_gate": wg_pad, "we_up": wu_pad, "we_down": wd_pad}
        out_buf = _expert_compute(cfg, pl_pad, xbuf, gs)
        w = top_w.reshape(-1)[order].astype(out_buf.dtype) * kept.astype(out_buf.dtype)
        out = jnp.zeros_like(xf).at[tok].add(out_buf * w[:, None])
        out = jax.lax.psum(out, axis_name)                      # the monoid combine
        stat_axes = (axis_name,) + batch_axes                   # total over fleet
        load = jax.lax.psum(
            jnp.zeros((E,), jnp.int32).at[e0 + jnp.arange(E_local)].set(
                gs[:E_local].astype(jnp.int32)), stat_axes)
        dropped = jax.lax.psum(
            (is_mine.sum() - kept.sum()).astype(jnp.int32), stat_axes)
        return out.reshape(Bl, Sl, D), load, dropped

    xspec = Pspec(batch_axes if batch_axes else None)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, Pspec(), Pspec(axis_name), Pspec(axis_name), Pspec(axis_name)),
        out_specs=(xspec, Pspec(), Pspec()),
        check_vma=False)
    out, load, dropped = fn(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    stats = {"expert_load": load, "dropped": dropped}
    if cfg.num_shared_experts > 0:
        out = out + dense_ffn(p["shared"], cfg, x)
    return out, stats


def moe_ffn_a2a(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh,
                *, axis_name: str = "model",
                batch_axes: Tuple[str, ...] = ("pod", "data")
                ) -> Tuple[jnp.ndarray, Dict]:
    """GShard-style dispatch: all_to_all tokens to expert owners and back.

    Each device packs, for every destination shard d, a capacity-C buffer of
    its tokens routed to d's experts. One all_to_all moves the buffers; the
    owner runs its experts; a second all_to_all returns outputs; a local
    weighted scatter-add (the Sum monoid) combines the k contributions.
    Wire bytes: 2 * P_send * C * D vs the replicated strategy's psum of the
    full (T, D) — the combiner-vs-naive byte reduction, measured in §Perf.

    The token set is PARTITIONED over the expert axis (seq-sharded into the
    shard_map) so each device routes a disjoint T/P slice — without this the
    expert axis holds replicated copies and every expert receives each token
    P times (§Perf iteration 6: the first a2a attempt cost 13x compute).
    Requires S % P == 0; smaller batches fall back to `replicated`.
    """
    B, S, D = x.shape
    E = cfg.num_experts
    P = mesh.shape[axis_name]
    assert E % P == 0, (E, P)
    if S % P != 0:
        return moe_ffn_replicated(p, cfg, x, mesh, axis_name=axis_name,
                                  batch_axes=batch_axes)
    E_local = E // P
    batch_axes = _divisible_batch_axes(mesh, batch_axes, B)
    Pspec = jax.sharding.PartitionSpec

    def body(xl, router, wg, wu, wd):
        pl = {"router": router}
        Bl, Sl = xl.shape[0], xl.shape[1]
        xf = xl.reshape(-1, D)
        T = Bl * Sl
        k = cfg.moe_top_k
        # per-destination capacity: tokens I send to each of P shards
        C = _capacity(cfg, T, P)
        top_e, top_w = route(pl, cfg, xf)                       # (T,k)
        flat_e = top_e.reshape(-1)                              # (T*k,)
        dst = flat_e // E_local                                 # owning shard
        # stable sort by destination; position within destination = rank
        order = jnp.argsort(dst, stable=True)
        dst_sorted = dst[order]
        # rank within each destination group
        idx = jnp.arange(dst_sorted.shape[0], dtype=jnp.int32)
        seg_start = jnp.full((P,), dst_sorted.shape[0], jnp.int32).at[
            dst_sorted].min(idx, mode="drop")
        rank = idx - seg_start[dst_sorted]
        keep = rank < C                                         # capacity drop
        tok_sorted = order // k
        slot = dst_sorted * C + rank                            # flat send slot
        send_x = jnp.zeros((P * C, D), xl.dtype).at[
            jnp.where(keep, slot, P * C)].set(xf[tok_sorted], mode="drop")
        send_e = jnp.full((P * C,), E_local, jnp.int32).at[
            jnp.where(keep, slot, P * C)].set(
                (flat_e[order] % E_local).astype(jnp.int32), mode="drop")
        # shuffle: (P, C, D) -> one buffer from each source shard
        recv_x = jax.lax.all_to_all(send_x.reshape(P, C, D), axis_name,
                                    split_axis=0, concat_axis=0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e.reshape(P, C), axis_name,
                                    split_axis=0, concat_axis=0, tiled=False)
        # local expert compute over the received (P*C) tokens
        rx = recv_x.reshape(P * C, D)
        re = recv_e.reshape(P * C)
        rorder = jnp.argsort(re)                                # sort by local expert
        gs_full = jnp.bincount(re, length=E_local + 1)
        wd_pad = jnp.concatenate([wd, jnp.zeros_like(wd[:1])], 0)
        wg_pad = jnp.concatenate([wg, jnp.zeros_like(wg[:1])], 0)
        wu_pad = jnp.concatenate([wu, jnp.zeros_like(wu[:1])], 0)
        out_sorted = _expert_compute(
            cfg, {"we_gate": wg_pad, "we_up": wu_pad, "we_down": wd_pad},
            rx[rorder], gs_full)
        out_r = jnp.zeros_like(rx).at[rorder].set(out_sorted)
        # shuffle back
        back = jax.lax.all_to_all(out_r.reshape(P, C, D), axis_name,
                                  split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(P * C, D)
        # combine: weighted scatter-add of the k expert contributions
        w_sorted = top_w.reshape(-1)[order].astype(xl.dtype)
        contrib = back[jnp.where(keep, slot, 0)] * (
            w_sorted * keep.astype(xl.dtype))[:, None]
        out = jnp.zeros_like(xf).at[tok_sorted].add(contrib)
        load = jnp.bincount(flat_e, length=E).astype(jnp.int32)
        dropped = (~keep).sum().astype(jnp.int32)
        # tokens are partitioned over (batch axes x expert axis): total stats
        stat_axes = batch_axes + (axis_name,)
        load = jax.lax.psum(load, stat_axes)
        dropped = jax.lax.psum(dropped, stat_axes)
        return out.reshape(Bl, Sl, D), load, dropped

    # tokens partitioned: batch over the data axes AND seq over the expert axis
    xspec = Pspec(batch_axes if batch_axes else None, axis_name)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, Pspec(), Pspec(axis_name), Pspec(axis_name),
                  Pspec(axis_name)),
        out_specs=(xspec, Pspec(), Pspec()),
        check_vma=False)
    out, load, dropped = fn(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    stats = {"expert_load": load, "dropped": dropped}
    if cfg.num_shared_experts > 0:
        out = out + dense_ffn(p["shared"], cfg, x)
    return out, stats


def moe_ffn(p: Dict, cfg: ModelConfig, x: jnp.ndarray, *, mesh=None,
            impl: str = "replicated", axis_name: str = "model") -> Tuple[jnp.ndarray, Dict]:
    """Dispatch to the configured expert-parallel strategy."""
    if mesh is None or axis_name not in getattr(mesh, "shape", {}) \
            or mesh.shape.get(axis_name, 1) == 1 \
            or cfg.num_experts % max(mesh.shape.get(axis_name, 1), 1) != 0:
        return moe_ffn_local(p, cfg, x)
    if impl == "replicated":
        return moe_ffn_replicated(p, cfg, x, mesh, axis_name=axis_name)
    if impl == "a2a":
        return moe_ffn_a2a(p, cfg, x, mesh, axis_name=axis_name)
    raise ValueError(impl)
