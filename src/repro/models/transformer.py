"""Model assembly: the period-scanned transformer covering all 10 archs.

A config's ``layer_pattern`` (e.g. jamba's ``(m,m,m,m,attn,m,m,m)``) is the
repeating *period*; the stack is executed as

    prelude layers (unrolled; DeepSeek's first-k-dense)
    -> lax.scan over num_periods, each step running one full period
    -> remainder layers (unrolled; gemma3's 26 = 4*6 + 2)

Parameters for the scanned region are stacked over periods (MaxText-style),
keeping HLO size O(period) instead of O(layers). Each scanned period is
rematerialized (jax.checkpoint) so live activations are O(period) too.

Decode runs the same program with per-slot caches carried through the scan
(KV for attention, latent for MLA, (h, conv) for mamba, (C, n) for mLSTM,
(h, c) for sLSTM, projected context-KV for cross-attention).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamBuilder, dense, embed_lookup, rms_norm
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from ..dist import sharding as shd

Pytree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(pb: ParamBuilder, cfg: ModelConfig, ltype: str, ftype: str) -> None:
    pb.param("norm1", (cfg.d_model,), ("embed",), init="ones")
    mix = pb.child("mix")
    if ltype in ("attn", "local"):
        if cfg.use_mla:
            attn.init_mla(mix, cfg)
        else:
            attn.init_attn(mix, cfg)
    elif ltype == "xattn":
        attn.init_cross_attn(mix, cfg)
    elif ltype == "mamba":
        ssm.init_mamba(mix, cfg)
    elif ltype == "mlstm":
        ssm.init_mlstm(mix, cfg)
    elif ltype == "slstm":
        ssm.init_slstm(mix, cfg)
    else:
        raise ValueError(ltype)
    if cfg.decoder_cross_attn and ltype in ("attn", "local"):
        xa = pb.child("xattn")
        pb.param("norm_x", (cfg.d_model,), ("embed",), init="ones")
        attn.init_cross_attn(xa, cfg)
    if ftype != "none":
        pb.param("norm2", (cfg.d_model,), ("embed",), init="ones")
        f = pb.child("ffn")
        if ftype == "dense":
            moe_mod.init_dense_ffn(f, cfg)
        elif ftype == "moe":
            moe_mod.init_moe(f, cfg)
        else:
            raise ValueError(ftype)


def _layer_plan(cfg: ModelConfig) -> Tuple[List[Tuple[str, str]], List[Tuple[str, str]], List[Tuple[str, str]]]:
    """-> (prelude, period_slots, remainder) lists of (ltype, ftype)."""
    P = cfg.period
    pre = cfg.prelude_dense_layers
    assert pre % P == 0 or P == 1 or pre == 0, "prelude must align with period"
    types = [(cfg.block_type(i), cfg.ffn_type(i)) for i in range(cfg.num_layers)]
    prelude = types[:pre]
    rest = types[pre:]
    n_main = (len(rest) // P) * P
    period_slots = rest[:P] if n_main else []
    remainder = rest[n_main:]
    return prelude, period_slots, remainder


def _num_periods(cfg: ModelConfig) -> int:
    return (cfg.num_layers - cfg.prelude_dense_layers) // cfg.period


def init_params(cfg: ModelConfig, key: Optional[jax.Array], *,
                abstract: bool = False) -> Tuple[Pytree, Pytree]:
    """Build (params, logical_axes). abstract=True builds ShapeDtypeStructs
    (no allocation — the dry-run path)."""
    pb = ParamBuilder(key, cfg.dtype, abstract=abstract)
    pb.param("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
             scale=cfg.d_model)
    prelude, period_slots, remainder = _layer_plan(cfg)
    n_periods = _num_periods(cfg)

    for i, (lt, ft) in enumerate(prelude):
        _init_layer(pb.child(f"prelude_{i}"), cfg, lt, ft)

    if period_slots:
        # one period's params, then stacked over periods via vmapped init
        def init_one_period(k):
            sub = ParamBuilder(k, cfg.dtype, abstract=abstract)
            for j, (lt, ft) in enumerate(period_slots):
                _init_layer(sub.child(f"slot_{j}"), cfg, lt, ft)
            return sub.params, sub.axes

        if abstract:
            one, one_axes = init_one_period(None)
            stacked = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype), one)
        else:
            keys = jax.random.split(pb._next_key(), n_periods)
            stacked = jax.vmap(lambda k: init_one_period(k)[0])(keys)
            one_axes = init_one_period(keys[0])[1]
        pb.params["layers"] = stacked
        pb.axes["layers"] = jax.tree_util.tree_map(
            lambda ax: ("layers",) + tuple(ax), one_axes,
            is_leaf=lambda x: isinstance(x, tuple) and (
                len(x) == 0 or isinstance(x[0], (str, type(None)))))

    for i, (lt, ft) in enumerate(remainder):
        _init_layer(pb.child(f"rem_{i}"), cfg, lt, ft)

    pb.param("final_norm", (cfg.d_model,), ("embed",), init="ones")
    if not cfg.tie_embeddings:
        pb.param("lm_head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                 scale=cfg.d_model)

    if cfg.encoder_layers > 0:  # whisper encoder (conv frontend is a stub)
        enc = pb.child("encoder")
        enc_cfg = dataclasses.replace(cfg, decoder_cross_attn=False,
                                      num_layers=cfg.encoder_layers)
        for i in range(cfg.encoder_layers):
            _init_layer(enc.child(f"layer_{i}"), enc_cfg, "attn", "dense")
        enc.param("final_norm", (cfg.d_model,), ("embed",), init="ones")

    return pb.params, pb.axes


def param_axes(cfg: ModelConfig) -> Pytree:
    """Logical-axes pytree without materializing params."""
    return init_params(cfg, None, abstract=True)[1]


def param_shapes(cfg: ModelConfig) -> Pytree:
    """ShapeDtypeStruct pytree without materializing params (dry-run path)."""
    return init_params(cfg, None, abstract=True)[0]


# ---------------------------------------------------------------------------
# layer application (training / prefill)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Execution-context knobs threaded through the stack (static)."""
    mesh: Any = None
    moe_impl: str = "replicated"
    attn_chunk: Optional[int] = None    # chunked-attention KV chunk (AttnState)
    ce_chunk: int = 0                   # chunked cross-entropy (0 = off)
    remat: str = "full"                 # full | none
    decode_impl: str = "dense"          # dense | flash (sharded-KV decode)


def _apply_ffn(p: Dict, cfg: ModelConfig, ftype: str, x: jnp.ndarray,
               ctx: RunCtx, stats: Optional[Dict]):
    if ftype == "none":
        return x, stats
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if ftype == "dense":
        out = moe_mod.dense_ffn(p["ffn"], cfg, h)
    else:
        out, s = moe_mod.moe_ffn(p["ffn"], cfg, h, mesh=ctx.mesh, impl=ctx.moe_impl)
        if stats is not None:
            stats = jax.tree_util.tree_map(jnp.add, stats, s)
    return x + out, stats


def _apply_layer(p: Dict, cfg: ModelConfig, ltype: str, ftype: str,
                 x: jnp.ndarray, positions: jnp.ndarray,
                 context: Optional[jnp.ndarray], ctx: RunCtx,
                 stats: Optional[Dict], *, causal: bool = True):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if ltype in ("attn", "local"):
        window = cfg.sliding_window if ltype == "local" else None
        if cfg.use_mla:
            mixed = attn.mla_attention(p["mix"], cfg, h, positions)
        else:
            mixed = attn.attention(p["mix"], cfg, h, positions, window=window,
                                   chunk_size=ctx.attn_chunk) if causal else \
                attn.attention_bidir(p["mix"], cfg, h, positions)
    elif ltype == "xattn":
        kv = attn.cross_kv(p["mix"], cfg, context)
        mixed = attn.cross_attention(p["mix"], cfg, h, kv, gated=True)
    elif ltype == "mamba":
        mixed = ssm.mamba_mix(p["mix"], cfg, h)
    elif ltype == "mlstm":
        mixed = ssm.mlstm_mix(p["mix"], cfg, h)
    elif ltype == "slstm":
        mixed = ssm.slstm_mix(p["mix"], cfg, h)
    else:
        raise ValueError(ltype)
    x = x + mixed
    if cfg.decoder_cross_attn and ltype in ("attn", "local") and context is not None:
        h = rms_norm(x, p["norm_x"], cfg.norm_eps)
        kv = attn.cross_kv(p["xattn"], cfg, context)
        x = x + attn.cross_attention(p["xattn"], cfg, h, kv)
    return _apply_ffn(p, cfg, ftype, x, ctx, stats)


def _zero_stats(cfg: ModelConfig) -> Optional[Dict]:
    if cfg.num_experts == 0:
        return None
    return {"expert_load": jnp.zeros((cfg.num_experts,), jnp.int32),
            "dropped": jnp.zeros((), jnp.int32)}


def forward(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray, *,
            context: Optional[jnp.ndarray] = None,
            ctx: RunCtx = RunCtx()) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Token ids -> final hidden states (B, S, D) + MoE stats (Sum monoid)."""
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    x = shd.act(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    stats = _zero_stats(cfg)

    if cfg.encoder_layers > 0 and context is not None:
        context = encode(params, cfg, context, ctx=ctx)

    prelude, period_slots, remainder = _layer_plan(cfg)
    for i, (lt, ft) in enumerate(prelude):
        x, stats = _apply_layer(params[f"prelude_{i}"], cfg, lt, ft, x,
                                positions, context, ctx, stats)

    if period_slots:
        def period_body(carry, slot_params):
            x, stats = carry
            for j, (lt, ft) in enumerate(period_slots):
                x, stats = _apply_layer(slot_params[f"slot_{j}"], cfg, lt, ft,
                                        x, positions, context, ctx, stats)
            return (x, stats), None

        body = period_body
        if ctx.remat == "full":
            body = jax.checkpoint(period_body, prevent_cse=True)
        (x, stats), _ = jax.lax.scan(body, (x, stats), params["layers"])

    for i, (lt, ft) in enumerate(remainder):
        x, stats = _apply_layer(params[f"rem_{i}"], cfg, lt, ft, x,
                                positions, context, ctx, stats)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, stats


def encode(params: Pytree, cfg: ModelConfig, features: jnp.ndarray, *,
           ctx: RunCtx = RunCtx()) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (B, S_enc, D).

    Bidirectional attention; sinusoidal positions added to the stub features.
    """
    enc = params["encoder"]
    B, S, D = features.shape
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / D)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    x = (features + pe.astype(features.dtype)).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc_cfg = dataclasses.replace(cfg, decoder_cross_attn=False)
    for i in range(cfg.encoder_layers):
        x, _ = _apply_layer(enc[f"layer_{i}"], enc_cfg, "attn", "dense", x,
                            positions, None, ctx, None, causal=False)
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _ce_from_logits(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    loss = (lse - gold) * mask
    correct = (jnp.argmax(logits, axis=-1) == labels) & (mask > 0)
    return loss.sum(), correct.sum().astype(jnp.int32)


def unembed(params: Pytree, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jax.lax.dot_general(h, w.astype(h.dtype),
                                 (((h.ndim - 1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    return shd.act(logits, ("batch", "seq", "vocab"))


def loss_fn(params: Pytree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            ctx: RunCtx = RunCtx()) -> Tuple[jnp.ndarray, Dict]:
    """Mean CE loss + metrics (a Sum-monoid tuple: one psum for everything).

    ctx.ce_chunk > 0 enables chunked cross-entropy: the (S/V) logits are
    produced and folded chunk-by-chunk in a lax.scan — in-mapper combining of
    (loss_sum, correct) — so the full (B, S, V) logits are never live.
    """
    tokens = batch["tokens"]
    labels = batch["labels"]
    context = batch.get("context")
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    h, stats = forward(params, cfg, tokens, context=context, ctx=ctx)

    if ctx.ce_chunk and tokens.shape[1] % ctx.ce_chunk == 0:
        B, S = tokens.shape
        n_chunks = S // ctx.ce_chunk

        def chunked(t):
            return t.reshape((B, n_chunks, ctx.ce_chunk) + t.shape[2:]).swapaxes(0, 1)

        def step(acc, inp):
            hc, lc, mc = inp
            logits = unembed(params, cfg, hc)
            ls, cr = _ce_from_logits(logits, lc, mc)
            return (acc[0] + ls, acc[1] + cr), None

        (loss_sum, correct), _ = jax.lax.scan(
            jax.checkpoint(step, prevent_cse=True) if ctx.remat == "full" else step,
            (jnp.zeros(()), jnp.zeros((), jnp.int32)),
            (chunked(h), chunked(labels_safe), chunked(mask)))
    else:
        logits = unembed(params, cfg, h)
        loss_sum, correct = _ce_from_logits(logits, labels_safe, mask)

    ntok = mask.sum()
    metrics = {"loss_sum": loss_sum, "tokens": ntok,
               "correct": correct.astype(jnp.float32)}
    if stats is not None:
        metrics["expert_load"] = stats["expert_load"].astype(jnp.float32)
        metrics["moe_dropped"] = stats["dropped"].astype(jnp.float32)
    loss = loss_sum / jnp.maximum(ntok, 1.0)
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ModelConfig, ltype: str, batch: int, max_seq: int,
                      params: Optional[Dict] = None,
                      context: Optional[jnp.ndarray] = None):
    if ltype in ("attn", "local"):
        if cfg.use_mla:
            base = {"lat_c": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), cfg.dtype),
                    "lat_r": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), cfg.dtype)}
        else:
            base = {"k": jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
                    "v": jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cfg.dtype)}
        if cfg.decoder_cross_attn and params is not None and context is not None:
            k, v = attn.cross_kv(params["xattn"], cfg, context)
            base["xk"], base["xv"] = k, v
        return base
    if ltype == "xattn":
        if params is not None and context is not None:
            k, v = attn.cross_kv(params["mix"], cfg, context)
            return {"xk": k, "xv": v}
        ctx_len = context.shape[1] if context is not None else cfg.num_image_tokens
        return {"xk": jnp.zeros((batch, ctx_len, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
                "xv": jnp.zeros((batch, ctx_len, cfg.num_kv_heads, cfg.head_dim), cfg.dtype)}
    if ltype == "mamba":
        return ssm.init_mamba_cache(cfg, batch)
    if ltype == "mlstm":
        return ssm.init_mlstm_cache(cfg, batch)
    if ltype == "slstm":
        return ssm.init_slstm_cache(cfg, batch)
    raise ValueError(ltype)


def positional_cache(cfg: ModelConfig) -> bool:
    """True when every decode-cache leaf (besides ``pos``) is indexed by
    absolute sequence position — KV/MLA-latent rows at position i depend
    only on tokens 0..i and on i itself (RoPE at absolute positions).

    This is the property prefix KV sharing relies on: a cached prefix's
    rows can be scattered into a fresh slot cache verbatim and the decode
    is bit-identical to recomputing them.  Recurrent state (mamba/*lstm)
    and context KV (cross-attention, encoder-decoder) are not row-per-
    position, so those archs opt out of the prefix cache.
    """
    if cfg.encoder_layers > 0 or cfg.decoder_cross_attn:
        return False
    return all(cfg.block_type(i) in ("attn", "local")
               for i in range(cfg.num_layers))


def init_cache(params: Pytree, cfg: ModelConfig, batch: int, max_seq: int, *,
               context: Optional[jnp.ndarray] = None,
               ctx: RunCtx = RunCtx(), pos_per_slot: bool = False) -> Pytree:
    """Decode caches, mirroring the layer program's structure.

    For enc-dec / vision models, the cross-attention context KV is projected
    ONCE here and reused by every decode step (in-mapper combining of the
    static context — DESIGN.md §4).

    ``pos_per_slot=True`` makes ``pos`` a ``(batch,)`` vector instead of a
    scalar: every batch row (request slot) carries its own cache position,
    which is what lets a continuously-batched engine retire a request and
    restart the freed slot at position 0 while its neighbours keep decoding.
    """
    if cfg.encoder_layers > 0 and context is not None:
        context = encode(params, cfg, context, ctx=ctx)
    prelude, period_slots, remainder = _layer_plan(cfg)
    pos0 = jnp.zeros((batch,) if pos_per_slot else (), jnp.int32)
    cache: Dict[str, Any] = {"pos": pos0}
    for i, (lt, _) in enumerate(prelude):
        cache[f"prelude_{i}"] = _init_layer_cache(
            cfg, lt, batch, max_seq, params[f"prelude_{i}"], context)
    if period_slots:
        n = _num_periods(cfg)

        def one_period(slot_params):
            return {f"slot_{j}": _init_layer_cache(cfg, lt, batch, max_seq,
                                                   slot_params[f"slot_{j}"], context)
                    for j, (lt, _) in enumerate(period_slots)}

        if context is not None:
            cache["layers"] = jax.vmap(one_period)(params["layers"])
        else:
            # no context: caches are identical zero-trees; build once and tile
            one = one_period(jax.tree_util.tree_map(lambda p: p[0], params["layers"]))
            cache["layers"] = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l, (n,) + l.shape), one)
    for i, (lt, _) in enumerate(remainder):
        cache[f"rem_{i}"] = _init_layer_cache(
            cfg, lt, batch, max_seq, params[f"rem_{i}"], context)
    return cache


def _decode_layer(p: Dict, cfg: ModelConfig, ltype: str, ftype: str,
                  x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
                  ctx: RunCtx):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if ltype in ("attn", "local"):
        window = cfg.sliding_window if ltype == "local" else None
        if cfg.use_mla:
            mixed, (c, r) = attn.mla_decode(p["mix"], cfg, h, (cache["lat_c"], cache["lat_r"]), pos)
            cache = {**cache, "lat_c": c, "lat_r": r}
        elif ctx.decode_impl == "flash" and ctx.mesh is not None:
            mixed, (k, v) = attn.flash_decode_shardmap(
                p["mix"], cfg, h, (cache["k"], cache["v"]), pos, ctx.mesh,
                window=window)
            cache = {**cache, "k": k, "v": v}
        else:
            mixed, (k, v) = attn.attention_decode(
                p["mix"], cfg, h, (cache["k"], cache["v"]), pos, window=window)
            cache = {**cache, "k": k, "v": v}
    elif ltype == "xattn":
        mixed = attn.cross_attention(p["mix"], cfg, h, (cache["xk"], cache["xv"]),
                                     gated=True)
    elif ltype == "mamba":
        mixed, new = ssm.mamba_decode(p["mix"], cfg, h, cache)
        cache = new
    elif ltype == "mlstm":
        mixed, new = ssm.mlstm_decode(p["mix"], cfg, h, cache)
        cache = new
    elif ltype == "slstm":
        mixed, new = ssm.slstm_decode(p["mix"], cfg, h, cache)
        cache = new
    else:
        raise ValueError(ltype)
    x = x + mixed
    if cfg.decoder_cross_attn and ltype in ("attn", "local") and "xk" in cache:
        h = rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], cfg, h, (cache["xk"], cache["xv"]))
    x, _ = _apply_ffn(p, cfg, ftype, x, ctx, None)
    return x, cache


def decode_step(params: Pytree, cfg: ModelConfig, cache: Pytree,
                tokens: jnp.ndarray, *, ctx: RunCtx = RunCtx()
                ) -> Tuple[jnp.ndarray, Pytree]:
    """One serving step: (B, 1) new tokens -> (B, 1, V) logits + new caches."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    x = shd.act(x, ("batch", None, "embed"))
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    prelude, period_slots, remainder = _layer_plan(cfg)
    for i, (lt, ft) in enumerate(prelude):
        x, new_cache[f"prelude_{i}"] = _decode_layer(
            params[f"prelude_{i}"], cfg, lt, ft, x, cache[f"prelude_{i}"], pos, ctx)

    if period_slots:
        def body(x, inp):
            slot_params, slot_cache = inp
            new_slots = {}
            for j, (lt, ft) in enumerate(period_slots):
                x, new_slots[f"slot_{j}"] = _decode_layer(
                    slot_params[f"slot_{j}"], cfg, lt, ft, x,
                    slot_cache[f"slot_{j}"], pos, ctx)
            return x, new_slots

        x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = new_layer_cache

    for i, (lt, ft) in enumerate(remainder):
        x, new_cache[f"rem_{i}"] = _decode_layer(
            params[f"rem_{i}"], cfg, lt, ft, x, cache[f"rem_{i}"], pos, ctx)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, new_cache
