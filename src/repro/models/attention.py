"""Attention in all the flavours the assigned pool needs.

* GQA with optional qk-norm (qwen3/gemma3), QKV bias (qwen2.5), RoPE.
* Sliding-window ('local') attention (gemma3's 5:1 local:global).
* Cross-attention (whisper decoder, llama-3.2-vision image layers).
* MLA — DeepSeek-V2 multi-head latent attention, with the weight-absorbed
  decode form (attention runs in the 576-dim latent space; the KV cache is
  ``kv_lora_rank + qk_rope_dim`` per token, shared across all 128 heads).
* Chunked attention + flash-decoding built on the ``attn_state`` monoid
  (repro.core.monoids): the running (m, l, o) softmax state is associative,
  so KV chunking / KV sharding across devices are legal re-bracketings —
  the paper's principle applied to softmax (DESIGN.md §2).

Shapes: x (B, S, D); q (B, S, H, hd); k,v (B, S, KV, hd).
Masks are built from positions so the same code serves train (S queries)
and decode (1 query against a cache).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common
from .common import ModelConfig, ParamBuilder, dense, rms_norm, rotary_embed
from ..dist import sharding as shd
from ..core import monoids
from ..core.aggregation import monoid_allreduce

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_attn(pb: ParamBuilder, cfg: ModelConfig) -> None:
    """Standard GQA projection weights into ``pb`` (one layer)."""
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pb.param("wq", (D, H, hd), ("embed", "heads", "head_dim"), scale=D)
    pb.param("wk", (D, KV, hd), ("embed", "kv_heads", "head_dim"), scale=D)
    pb.param("wv", (D, KV, hd), ("embed", "kv_heads", "head_dim"), scale=D)
    pb.param("wo", (H, hd, D), ("heads", "head_dim", "embed"), scale=H * hd)
    if cfg.qkv_bias:
        pb.param("bq", (H, hd), ("heads", "head_dim"), init="zeros")
        pb.param("bk", (KV, hd), ("kv_heads", "head_dim"), init="zeros")
        pb.param("bv", (KV, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        pb.param("q_norm", (hd,), ("head_dim",), init="ones")
        pb.param("k_norm", (hd,), ("head_dim",), init="ones")


def init_cross_attn(pb: ParamBuilder, cfg: ModelConfig) -> None:
    """Cross-attention: q from x, k/v from a context sequence."""
    init_attn(pb, cfg)
    pb.param("gate", (), (), init="zeros")   # llama-vision gated cross-attn


def init_mla(pb: ParamBuilder, cfg: ModelConfig) -> None:
    D, H = cfg.d_model, cfg.num_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    dr, dn, dv = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    if ql > 0:
        pb.param("wq_a", (D, ql), ("embed", "q_lora"), scale=D)
        pb.param("q_a_norm", (ql,), ("q_lora",), init="ones")
        pb.param("wq_b", (ql, H, dn + dr), ("q_lora", "heads", "head_dim"), scale=ql)
    else:
        pb.param("wq", (D, H, dn + dr), ("embed", "heads", "head_dim"), scale=D)
    pb.param("wkv_a", (D, kvl + dr), ("embed", "kv_lora"), scale=D)
    pb.param("kv_a_norm", (kvl,), ("kv_lora",), init="ones")
    pb.param("wk_b", (kvl, H, dn), ("kv_lora", "heads", "head_dim"), scale=kvl)
    pb.param("wv_b", (kvl, H, dv), ("kv_lora", "heads", "head_dim"), scale=kvl)
    pb.param("wo", (H, dv, D), ("heads", "head_dim", "embed"), scale=H * dv)


# ---------------------------------------------------------------------------
# q/k/v projection
# ---------------------------------------------------------------------------

def _project_qkv(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = rotary_embed(q, positions, cfg.rope_theta)
        k = rotary_embed(k, positions, cfg.rope_theta)
    q = shd.act(q, ("batch", "seq", "heads", None))
    k = shd.act(k, ("batch", "seq", "kv_heads", None))
    v = shd.act(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, scale: float) -> jnp.ndarray:
    """(B,Sq,H,hd) x (B,Sk,KV,hd) -> (B, H, Sq, Sk) with GQA head grouping."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    return s.reshape(B, KV * G, Sq, k.shape[1])


def _gqa_values(w: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """(B,H,Sq,Sk) x (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, H, Sq, Sk = w.shape
    KV = v.shape[2]
    G = H // KV
    wg = w.reshape(B, KV, G, Sq, Sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", wg, v)
    return o.reshape(B, Sq, H, v.shape[3])


def _causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                 window: Optional[int] = None) -> jnp.ndarray:
    """(…, Sq, Sk) boolean keep-mask: causal, optionally sliding-window."""
    keep = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        keep &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return keep


def _causal_bias(seq_len: int, window: Optional[int] = None) -> jnp.ndarray:
    """(S, S) additive f32 mask bias, shared across batch and heads.

    §Perf iteration 3: `where(keep, scores, -inf)` is a 3-operand select over
    the (B, H, S, S) scores — ~3 full passes of S^2 traffic per use, and the
    -inf broadcast materializes at (B,1,S,S). Adding a SHARED (S,S) bias
    reads S^2 * 4 bytes once (64MB at 4k) and turns masking into the cheap
    epilogue of the scores matmul. Valid whenever positions are the uniform
    arange (the whole training/prefill path)."""
    pos = jnp.arange(seq_len, dtype=jnp.int32)
    keep = _causal_mask(pos, pos, window)
    return jnp.where(keep, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# training / prefill attention (full sequence)
# ---------------------------------------------------------------------------

def attention(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
              *, window: Optional[int] = None,
              chunk_size: Optional[int] = None) -> jnp.ndarray:
    """Causal self-attention over the full sequence.

    chunk_size: if set, use the attn_state-monoid chunked form over the KV
    axis (memory O(S*chunk) instead of O(S^2) live scores).
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    if chunk_size is None:
        scale = 1.0 / math.sqrt(cfg.head_dim)
        scores = _gqa_scores(q, k, scale)                       # (B,H,Sq,Sk) fp32
        if common._F32_CHAINS:   # baseline program: 3-operand select masking
            keep = _causal_mask(positions, positions, window)[:, None]
            scores = jnp.where(keep, scores, NEG_INF)
        else:                    # §Perf iter 3: shared (S,S) additive bias
            scores = scores + _causal_bias(x.shape[1], window)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = _gqa_values(w, v)
    else:
        o = _chunked_attention(cfg, q, k, v, positions, positions,
                               window=window, chunk_size=chunk_size)
    o = shd.act(o, ("batch", "seq", "heads", None))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shd.act(out, ("batch", "seq", "embed"))


def attention_bidir(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional (encoder) self-attention — no mask, no RoPE (whisper
    encoder uses sinusoidal absolute positions added to the features)."""
    q, k, v = _project_qkv(p, cfg, x, positions, rope=False)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k, scale)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_values(w, v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shd.act(out, ("batch", "seq", "embed"))


def _attn_chunk_state(cfg, q, k, v, q_pos, k_pos, window):
    """Partial attn_state (m, l, o) of q against one KV chunk."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = _gqa_scores(q, k, scale)                           # (B,H,Sq,Ck) fp32
    keep = _causal_mask(q_pos, k_pos, window)[:, None]
    scores = jnp.where(keep, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)                                # (B,H,Sq)
    msafe = jnp.where(jnp.isneginf(m), 0.0, m)
    e = jnp.where(jnp.isneginf(scores), 0.0, jnp.exp(scores - msafe[..., None]))
    l = jnp.sum(e, axis=-1)
    o = _gqa_values(e.astype(v.dtype), v)                       # (B,Sq,H,hd)
    o = jnp.moveaxis(o, 1, 2).astype(jnp.float32)               # (B,H,Sq,hd)
    return (m, l, o)


def _chunked_attention(cfg, q, k, v, q_pos, k_pos, *, window, chunk_size):
    """Fold attn_state over KV chunks with lax.scan (in-mapper combining)."""
    B, Sk = k.shape[0], k.shape[1]
    assert Sk % chunk_size == 0, (Sk, chunk_size)
    n_chunks = Sk // chunk_size

    def chunks(t):
        return t.reshape(B, n_chunks, chunk_size, *t.shape[2:]).swapaxes(0, 1)

    kc, vc = chunks(k), chunks(v)
    kp = k_pos.reshape(B, n_chunks, chunk_size).swapaxes(0, 1) \
        if k_pos.ndim == 2 else k_pos.reshape(n_chunks, chunk_size)

    H, Sq, hd = q.shape[2], q.shape[1], v.shape[-1]
    init = (jnp.full((B, H, Sq), -jnp.inf),
            jnp.zeros((B, H, Sq)),
            jnp.zeros((B, H, Sq, hd)))

    def step(acc, chunk):
        kci, vci, kpi = chunk
        state = _attn_chunk_state(cfg, q, kci, vci, q_pos, kpi, window)
        return monoids.attn_state.combine(acc, state), None

    acc, _ = jax.lax.scan(step, init, (kc, vc, kp))
    o = monoids.attn_state.extract(acc)                         # (B,H,Sq,hd)
    return jnp.moveaxis(o, 1, 2).astype(q.dtype)                # (B,Sq,H,hd)


# ---------------------------------------------------------------------------
# decode attention (1 new token against a cache)
# ---------------------------------------------------------------------------

def decode_positions(pos: jnp.ndarray, batch: int) -> jnp.ndarray:
    """(B, 1) query positions from a scalar OR per-row ``pos``.

    Scalar ``pos`` is the classic aligned-batch decode (every row at the
    same position).  A ``(B,)`` ``pos`` is the continuous-batching case:
    each request slot carries its own position, so a freed slot can restart
    at 0 mid-decode while its neighbours keep generating.
    """
    if jnp.ndim(pos) == 1:
        return pos[:, None].astype(jnp.int32)
    return jnp.full((batch, 1), pos, jnp.int32)


def cache_span_update(cache: jnp.ndarray, new: jnp.ndarray,
                      pos: jnp.ndarray, *, seq_axis: int = 1) -> jnp.ndarray:
    """Write a contiguous span of rows into a cache at scalar or per-row
    positions.

    cache: (..., S, ...) with the sequence axis at ``seq_axis``; new is the
    same shape with span length L in place of S; pos: () or (B,) start
    positions (the batch axis is ``seq_axis - 1`` for the per-row form).
    The single-row case (L == 1) is the classic decode write; the span case
    is the prefix-cache scatter — a cached KV prefix lands in the slot cache
    in one write instead of L decode steps.
    """
    if jnp.ndim(pos) == 1:
        return jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n, i, axis=seq_axis - 1),
            in_axes=(seq_axis - 1, seq_axis - 1, 0),
            out_axes=seq_axis - 1)(cache, new, pos)
    return jax.lax.dynamic_update_slice_in_dim(cache, new, pos, axis=seq_axis)


def cache_row_update(cache: jnp.ndarray, new: jnp.ndarray,
                     pos: jnp.ndarray) -> jnp.ndarray:
    """Write one new-token slice into a cache at scalar or per-row positions.

    cache: (B, S, ...); new: (B, 1, ...); pos: () or (B,).  The per-row form
    is a vmapped dynamic_update_slice — each request slot writes at its own
    position (continuous batching).  One-row special case of
    :func:`cache_span_update`.
    """
    return cache_span_update(cache, new, pos, seq_axis=1)


def attention_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                     cache: Tuple[jnp.ndarray, jnp.ndarray], pos: jnp.ndarray,
                     *, window: Optional[int] = None,
                     kv_shards: int = 1) -> Tuple[jnp.ndarray, Tuple]:
    """One decode step. x: (B, 1, D); cache: (k, v) each (B, S, KV, hd);
    pos: () current position (tokens 0..pos-1 are valid in the cache), or
    (B,) per-slot positions for a continuously-batched cache — each row
    writes and masks at its own position, so rows stay independent and a
    reused slot's computation is identical to a fresh batch's.

    kv_shards > 1 requests flash-decoding: the KV cache's sequence axis is
    sharded over the 'model' mesh axis and partial attn_states are merged
    with the monoid (sequence-parallel decode for long_500k).
    """
    kcache, vcache = cache
    B, S = kcache.shape[0], kcache.shape[1]
    positions = decode_positions(pos, B)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    kcache = cache_row_update(kcache, k_new, pos)
    vcache = cache_row_update(vcache, v_new, pos)
    kcache = shd.act(kcache, ("batch", "kv_seq", "kv_heads", None))
    vcache = shd.act(vcache, ("batch", "kv_seq", "kv_heads", None))

    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, kcache, scale)                      # (B,H,1,S)
    keep = _causal_mask(positions, k_pos, window)[:, None]
    scores = jnp.where(keep, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_values(w, vcache)                                  # (B,1,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shd.act(out, ("batch", None, "embed")), (kcache, vcache)


def flash_decode_shardmap(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                          cache: Tuple[jnp.ndarray, jnp.ndarray],
                          pos: jnp.ndarray, mesh, *, axis_name: str = "model",
                          window: Optional[int] = None):
    """Flash-decoding over a sequence-sharded KV cache (explicit shard_map).

    Each device holds a contiguous S/P slice of the KV cache, computes the
    partial (m, l, o) attn_state for its slice, and the states are merged
    with one monoid_allreduce — the distributed combiner of DESIGN.md §2.
    Used by the long_500k serving path. The new token's (k, v) is written by
    the owning shard only.
    """
    if jnp.ndim(pos) == 1:
        raise NotImplementedError(
            "flash decode requires a scalar cache position; per-slot (B,) "
            "positions (continuous batching) run the dense decode path")
    P = mesh.shape[axis_name]
    B, S = cache[0].shape[0], cache[0].shape[1]
    S_local = S // P
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    scale = 1.0 / math.sqrt(cfg.head_dim)

    def body(kc, vc):
        idx = jax.lax.axis_index(axis_name)
        start = idx * S_local
        # write the new token's kv if it falls in our slice
        local_off = jnp.clip(pos - start, 0, S_local - 1)
        in_range = (pos >= start) & (pos < start + S_local)
        upd_k = jnp.where(in_range, k_new, jax.lax.dynamic_slice_in_dim(kc, local_off, 1, 1))
        upd_v = jnp.where(in_range, v_new, jax.lax.dynamic_slice_in_dim(vc, local_off, 1, 1))
        kc = jax.lax.dynamic_update_slice_in_dim(kc, upd_k, local_off, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, upd_v, local_off, axis=1)

        k_pos = start + jnp.arange(S_local, dtype=jnp.int32)
        k_pos = jnp.broadcast_to(k_pos, (B, S_local))
        scores = _gqa_scores(q, kc, scale)
        keep = _causal_mask(positions, k_pos, window)[:, None]
        scores = jnp.where(keep, scores, -jnp.inf)
        m = jnp.max(scores, axis=-1)
        msafe = jnp.where(jnp.isneginf(m), 0.0, m)
        e = jnp.where(jnp.isneginf(scores), 0.0, jnp.exp(scores - msafe[..., None]))
        l = jnp.sum(e, axis=-1)
        o = jnp.moveaxis(_gqa_values(e.astype(vc.dtype), vc), 1, 2).astype(jnp.float32)
        state = monoid_allreduce(monoids.attn_state, (m, l, o), axis_name)
        out = monoids.attn_state.extract(state)                 # (B,H,1,hd)
        return jnp.moveaxis(out, 1, 2).astype(x.dtype), kc, vc

    pspec = jax.sharding.PartitionSpec
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(pspec(None, axis_name), pspec(None, axis_name)),
        out_specs=(pspec(), pspec(None, axis_name), pspec(None, axis_name)),
        check_vma=False)
    o, kcache, vcache = fn(cache[0], cache[1])
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shd.act(out, ("batch", None, "embed")), (kcache, vcache)


def ring_attention_shardmap(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            mesh, *, axis_name: str = "model",
                            causal: bool = True, head_dim_scale: Optional[float] = None):
    """Ring attention: seq-sharded Q/K/V; K/V blocks rotate around the ring
    via collective_permute while each device folds partial AttnStates.

    The third re-bracketing of the softmax monoid (after chunked attention
    and flash-decoding): legal because the (m, l, o) combine is associative,
    so the order in which KV blocks arrive is irrelevant — each hop is one
    combiner application (DESIGN.md §2). q, k, v: (B, S, H/KV, hd) with the
    S axis sharded over ``axis_name``. GQA should be pre-broadcast
    (KV == H) or use equal heads; returns (B, S, H, hd) seq-sharded.
    """
    P = mesh.shape[axis_name]
    B, S, H, hd = q.shape
    S_local = S // P
    scale = head_dim_scale or (1.0 / math.sqrt(hd))
    perm = [(j, (j + 1) % P) for j in range(P)]

    def body(qc, kc, vc):
        idx = jax.lax.axis_index(axis_name)
        q_pos = (idx * S_local + jnp.arange(S_local, dtype=jnp.int32))
        q_pos = jnp.broadcast_to(q_pos, (B, S_local))
        init_acc = (jnp.full((B, H, S_local), -jnp.inf),
                    jnp.zeros((B, H, S_local)),
                    jnp.zeros((B, H, S_local, hd)))

        def hop(i, carry):
            kc, vc, acc = carry
            src = (idx - i) % P                  # who produced this block
            k_pos = src * S_local + jnp.arange(S_local, dtype=jnp.int32)
            k_pos = jnp.broadcast_to(k_pos, (B, S_local))
            scores = _gqa_scores(qc, kc, scale)
            if causal:
                keep = _causal_mask(q_pos, k_pos)[:, None]
                scores = jnp.where(keep, scores, -jnp.inf)
            m = jnp.max(scores, axis=-1)
            msafe = jnp.where(jnp.isneginf(m), 0.0, m)
            e = jnp.where(jnp.isneginf(scores), 0.0,
                          jnp.exp(scores - msafe[..., None]))
            l = jnp.sum(e, axis=-1)
            o = jnp.moveaxis(_gqa_values(e.astype(vc.dtype), vc), 1, 2)
            state = (m, l, o.astype(jnp.float32))
            acc = monoids.attn_state.combine(acc, state)
            kc = jax.lax.ppermute(kc, axis_name, perm)
            vc = jax.lax.ppermute(vc, axis_name, perm)
            return (kc, vc, acc)

        _, _, acc = jax.lax.fori_loop(0, P, hop, (kc, vc, init_acc))
        out = monoids.attn_state.extract(acc)    # (B,H,S_local,hd)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)

    pspec = jax.sharding.PartitionSpec
    spec = pspec(None, axis_name)
    fn = jax.shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                       out_specs=spec, check_vma=False)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder / llama-vision image layers)
# ---------------------------------------------------------------------------

def cross_attention(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    context_kv: Tuple[jnp.ndarray, jnp.ndarray],
                    *, gated: bool = False) -> jnp.ndarray:
    """Attend from x to a precomputed context (k, v) — no mask, no RoPE.

    context_kv is computed once per sequence by :func:`cross_kv` (for decode
    this is the paper's in-mapper combining of the static vision/audio
    context: computed once, reused every step).
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = context_kv
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = _gqa_scores(q, k, scale)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_values(w, v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    if gated:
        out = jnp.tanh(p["gate"].astype(out.dtype)) * out
    return shd.act(out, ("batch", "seq", "embed"))


def cross_kv(p: Dict, cfg: ModelConfig, context: jnp.ndarray):
    """Project a context sequence to (k, v) once (cached across decode steps)."""
    k = jnp.einsum("bsd,dhk->bshk", context, p["wk"].astype(context.dtype))
    v = jnp.einsum("bsd,dhk->bshk", context, p["wv"].astype(context.dtype))
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = shd.act(k, ("batch", "seq", "kv_heads", None))
    v = shd.act(v, ("batch", "seq", "kv_heads", None))
    return (k, v)


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------

def _mla_q(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    dr, dn = cfg.qk_rope_dim, cfg.qk_nope_dim
    if cfg.q_lora_rank > 0:
        cq = dense(x, p["wq_a"])
        cq = rms_norm(cq, p["q_a_norm"], cfg.norm_eps)
        q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rotary_embed(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    """x -> (c_kv normalized, k_rope rotated): exactly what the MLA cache holds."""
    kvl, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv = dense(x, p["wkv_a"])                                  # (B,S,kvl+dr)
    c, k_rope = ckv[..., :kvl], ckv[..., kvl:]
    c = rms_norm(c, p["kv_a_norm"], cfg.norm_eps)
    k_rope = rotary_embed(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, k_rope


def mla_attention(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray) -> jnp.ndarray:
    """Training/prefill MLA: up-project the latent, standard MHA."""
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsl,lhk->bshk", c, p["wv_b"].astype(x.dtype))
    scale = 1.0 / math.sqrt(dn + cfg.qk_rope_dim)
    s_nope = jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    if common._F32_CHAINS:
        keep = _causal_mask(positions, positions)[:, None]
        scores = jnp.where(keep, scores, NEG_INF)
    else:
        scores = scores + _causal_bias(x.shape[1])
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqs,bshk->bqhk", w, v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shd.act(out, ("batch", "seq", "embed"))


def mla_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
               cache: Tuple[jnp.ndarray, jnp.ndarray],
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple]:
    """Weight-absorbed MLA decode: attention runs in the latent space.

    cache = (c (B,S,kvl), k_rope (B,S,dr)). Per step:
      q_nope' = q_nope @ wk_b^T  (absorb the k up-projection into q)
      scores  = q_nope' . c  +  q_rope . k_rope
      o_latent = softmax(scores) @ c          (B,1,H,kvl)
      o        = o_latent @ wv_b  then wo     (absorb the v up-projection)

    The cache is (kvl + dr) floats/token shared across ALL heads — the MLA
    memory-term win reported in the roofline table.
    """
    c_cache, r_cache = cache
    B, S = c_cache.shape[0], c_cache.shape[1]
    positions = decode_positions(pos, B)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)               # (B,1,H,*)
    c_new, r_new = _mla_latent(p, cfg, x, positions)
    c_cache = cache_row_update(c_cache, c_new, pos)
    r_cache = cache_row_update(r_cache, r_new, pos)
    c_cache = shd.act(c_cache, ("batch", "kv_seq", None))
    r_cache = shd.act(r_cache, ("batch", "kv_seq", None))

    # absorb wk_b into q: (B,1,H,dn) x (kvl,H,dn) -> (B,1,H,kvl)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, p["wk_b"].astype(x.dtype))
    s_lat = jnp.einsum("bqhl,bsl->bhqs", q_lat, c_cache,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhk,bsk->bhqs", q_rope, r_cache,
                        preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (s_lat + s_rope) * scale
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    keep = _causal_mask(positions, k_pos)[:, None]
    scores = jnp.where(keep, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", w, c_cache)            # (B,1,H,kvl)
    o = jnp.einsum("bqhl,lhv->bqhv", o_lat, p["wv_b"].astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shd.act(out, ("batch", None, "embed")), (c_cache, r_cache)
