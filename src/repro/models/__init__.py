"""repro.models — pure-JAX model substrate for the assigned architecture pool."""
from .common import ModelConfig, ParamBuilder, rms_norm, rotary_embed
from .transformer import (RunCtx, decode_step, encode, forward, init_cache,
                          init_params, loss_fn, param_axes, param_shapes,
                          positional_cache, unembed)
from . import attention, moe, ssm
