"""monoidax — the paper's monoid principle as the aggregation layer of a
multi-pod JAX training/inference framework.

Subpackages:
  core        the Monoid abstraction, zoo, folds, MapReduce engine
  models      the 10-arch pure-JAX model substrate
  configs     assigned architectures x input-shape cells
  dist        logical-axis sharding rules
  optim       AdamW, schedules, EF gradient compression
  data        deterministic pipeline + sketch statistics
  checkpoint  atomic/async/mesh-agnostic checkpoints
  runtime     preemption / elastic re-mesh / stragglers
  kernels     Pallas TPU kernels (+ interpret-mode validation)
  launch      meshes, step builders, dry-run, roofline analyzer
  serving     continuous-batching serve engine (stable public facade)

The core fold API is re-exported here — ``repro.Monoid``,
``repro.execute_fold``, ``repro.plan_fold``, ``repro.MapReduceJob`` — so
applications don't import from ``repro.core.plan`` internals.
"""
from . import _compat  # noqa: F401  (installs jax API shims; must run first)

from .core import (MapReduceJob, Monoid, execute_fold, monoids,  # noqa: E402
                   plan_fold)

__version__ = "0.1.0"

__all__ = ["MapReduceJob", "Monoid", "execute_fold", "monoids", "plan_fold",
           "__version__"]
