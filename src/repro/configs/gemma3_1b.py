"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; 5:1 local:global, 128k context. [hf:google/gemma-3-1b-pt]

Layer program: period of 6 = 5 sliding-window ('local', window 512) + 1
global layer; 26 = 4 full periods + 2 remainder local layers (unrolled).
head_dim=256 (4 x 256 != d_model — gemma3 uses wide heads). Embeddings are
scaled by sqrt(d_model) and tied.
"""
from ..models import ModelConfig

ARCH_ID = "gemma3-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
        head_dim=256, d_ff=6912, vocab_size=262144,
        qk_norm=True, rope_theta=1_000_000.0, sliding_window=512,
        layer_pattern=("local",) * 5 + ("attn",), ffn_pattern=("dense",) * 6,
        scale_embed=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512,
        qk_norm=True, sliding_window=8,
        layer_pattern=("local",) * 5 + ("attn",), ffn_pattern=("dense",) * 6,
        scale_embed=True, tie_embeddings=True,
    )
