"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA, QKV bias. [hf:Qwen/Qwen2.5 family; hf]"""
from ..models import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=13824, vocab_size=152064,
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=512, qkv_bias=True,
    )
