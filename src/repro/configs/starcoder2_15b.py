"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA, RoPE, non-gated GELU MLP, bias. [arXiv:2402.19173; hf]"""
from ..models import ModelConfig

ARCH_ID = "starcoder2-15b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        head_dim=128, d_ff=24576, vocab_size=49152,
        qkv_bias=True, rope_theta=100_000.0,
        act_fn="gelu", gated_ffn=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=8, num_kv_heads=4,
        head_dim=8, d_ff=256, vocab_size=512,
        qkv_bias=True, act_fn="gelu", gated_ffn=False,
    )
