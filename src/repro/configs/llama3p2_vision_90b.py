"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; gated cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision family]

The vision tower is a STUB per the assignment: ``input_specs`` feeds
precomputed patch embeddings (B, 1600, d_model). Period of 5 = 4 self-attn
+ 1 gated cross-attn layer (20 cross-attn layers in 100).
"""
from ..models import ModelConfig

ARCH_ID = "llama-3.2-vision-90b"

_PATTERN = ("attn", "attn", "attn", "attn", "xattn")


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=128256,
        layer_pattern=_PATTERN, ffn_pattern=("dense",) * 5,
        num_image_tokens=1600, rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        layer_pattern=_PATTERN, ffn_pattern=("dense",) * 5,
        num_image_tokens=12,
    )
