"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2; Mamba+attn 1:7 interleave. [arXiv:2403.19887; hf]

Layer program: period of 8 with attention at slot 4 (jamba's
attn_layer_period=8, attn_layer_offset=4); MoE FFN on every other layer
(expert_layer_period=2, offset=1). Runs long_500k: the mamba state is O(1)
and the 4 attention layers flash-decode over a sequence-sharded KV cache.
"""
from ..models import ModelConfig

ARCH_ID = "jamba-v0.1-52b"

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")
_FFN = ("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe")


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=65536,
        layer_pattern=_PATTERN, ffn_pattern=_FFN,
        num_experts=16, moe_top_k=2, d_ff_expert=14336,
        norm_topk_prob=True,
        d_state=16, d_conv=4, ssm_expand=2,
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        layer_pattern=_PATTERN, ffn_pattern=_FFN,
        num_experts=8, moe_top_k=2, d_ff_expert=64,
        d_state=8, d_conv=4, ssm_expand=2,
        subquadratic=True,
    )
