"""Architecture registry + assigned input-shape cells.

10 assigned architectures x 4 shapes = 40 cells; ``valid_cells`` filters the
per-spec skips (long_500k only for sub-quadratic archs; every arch here has a
decoder so decode shapes always run). See DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelConfig
from . import (deepseek_v2_236b, gemma3_1b, jamba_v0p1_52b, llama3p2_vision_90b,
               qwen2_moe_a2p7b, qwen2p5_14b, qwen3_0p6b, starcoder2_15b,
               whisper_small, xlstm_1p3b)

_MODULES = {
    m.ARCH_ID: m for m in (
        qwen3_0p6b, gemma3_1b, qwen2p5_14b, starcoder2_15b, jamba_v0p1_52b,
        deepseek_v2_236b, qwen2_moe_a2p7b, whisper_small, xlstm_1p3b,
        llama3p2_vision_90b,
    )
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[arch_id]
    return mod.smoke_config() if smoke else mod.config()


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def valid_cells(cfg: ModelConfig) -> List[str]:
    """Per-spec skips: long_500k needs sub-quadratic attention."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        cells.append("long_500k")
    return cells


def context_spec(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    """Stubbed modality frontend output (audio frames / vision patches)."""
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the data inputs of one cell.

    train  -> {tokens, labels[, context]}
    prefill-> {tokens[, context]}
    decode -> {tokens (B,1)[, context]}; the KV-cache specs are derived by the
              launcher via eval_shape of init_cache (launch/dryrun.py).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    ctx = context_spec(cfg, B)
    if ctx is not None:
        specs["context"] = ctx
    return specs
