"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from ..models import ModelConfig

ARCH_ID = "qwen3-0.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=3072, vocab_size=151936,
        qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
    )
