"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) d_ff=1408 (expert)
vocab=151936, MoE 60e top-4 + 4 shared. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 routed experts are padded to 64 for clean 16-way EP divisibility; the 4
pad experts are masked to -inf in the router and never receive tokens
(see models/moe.py::route). Shared expert capacity = 4 x 1408 = 5632.
"""
from ..models import ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=151936,
        layer_pattern=("attn",), ffn_pattern=("moe",),
        num_experts=64, num_padded_experts=4,
        num_shared_experts=4, moe_top_k=4, d_ff_expert=1408,
        norm_topk_prob=False, qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=512,
        layer_pattern=("attn",), ffn_pattern=("moe",),
        num_experts=8, num_padded_experts=1,
        num_shared_experts=2, moe_top_k=2, d_ff_expert=32,
        norm_topk_prob=False, qkv_bias=True,
    )
