"""whisper-small [audio] — 12L (enc) + 12L (dec) d_model=768 12H d_ff=3072
vocab=51865; enc-dec, conv frontend STUB. [arXiv:2212.04356]

The conv1d audio frontend is a stub per the assignment: ``input_specs``
feeds precomputed frame embeddings (B, 1500, 768). Deviations recorded in
DESIGN.md: decoder uses RoPE instead of learned absolute positions (the
assigned decode shapes need a 32k cache; whisper's learned table stops at
448), and norms are RMSNorm.
"""
from ..models import ModelConfig

ARCH_ID = "whisper-small"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=51865,
        encoder_layers=12, encoder_seq=1500,
        act_fn="gelu", gated_ffn=False, decoder_cross_attn=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512,
        encoder_layers=2, encoder_seq=24,
        act_fn="gelu", gated_ffn=False, decoder_cross_attn=True,
    )
