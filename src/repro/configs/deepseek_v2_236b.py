"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 (expert)
vocab=102400, MoE 160e top-6 + 2 shared; MLA kv_lora=512. [arXiv:2405.04434]

MLA: q_lora_rank=1536, kv_lora_rank=512, qk_rope=64, qk_nope=128, v=128.
Decode uses the weight-absorbed form: the KV cache is (512+64) floats/token
shared across all 128 heads — the MLA memory-term reduction shows directly
in the decode_32k roofline row. First layer FFN is dense (d_ff 12288,
first_k_dense_replace=1); the other 59 are MoE. 160 experts / 16-way EP = 10
experts per device.
"""
from ..models import ModelConfig

ARCH_ID = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=12288, vocab_size=102400,
        layer_pattern=("attn",), ffn_pattern=("moe",),
        prelude_dense_layers=1,
        num_experts=160, num_shared_experts=2, moe_top_k=6, d_ff_expert=1536,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128, head_dim=192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        layer_pattern=("attn",), ffn_pattern=("moe",),
        prelude_dense_layers=1,
        num_experts=8, num_shared_experts=2, moe_top_k=2, d_ff_expert=32,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16, head_dim=24,
    )
