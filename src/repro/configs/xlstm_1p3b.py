"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks in 7:1 ratio (xLSTM[7:1]). [arXiv:2405.04517]

Blocks carry their own up/down projections (mLSTM pf=2, sLSTM gated MLP
pf=4/3) so ffn_pattern is 'none' everywhere (d_ff=0 per the assignment).
O(1) recurrent state => runs long_500k. Chunkwise-parallel mLSTM via the
affine-scan monoid; simplifications vs the paper are listed in DESIGN.md.
"""
from ..models import ModelConfig

ARCH_ID = "xlstm-1.3b"

_PATTERN = ("mlstm",) * 7 + ("slstm",)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        head_dim=512, d_ff=0, vocab_size=50304,
        layer_pattern=_PATTERN, ffn_pattern=("none",) * 8,
        mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=0, vocab_size=512,
        layer_pattern=_PATTERN, ffn_pattern=("none",) * 8,
        subquadratic=True,
    )
