"""The paper's MapReduce algorithms (1-5), executable on a TPU mesh.

A :class:`MapReduceJob` is the paper's program model made static-shaped for
XLA:

    mapper  : record -> (key, raw_value)      key in [0, num_keys)
    monoid  : lift/combine/identity/extract over the intermediate value
    reducer : the monoid combine + extract (never user-written — that is
              exactly the paper's point)

Three executable strategies mirror the paper's algorithms:

* ``naive``     — Algorithm 1: mappers emit every lifted pair; ALL pairs cross
                  the wire; reducers fold.
* ``combiner``  — Algorithm 3: lifted pairs are materialized on-device, a
                  combiner segment-folds them into a dense per-key table
                  before the shuffle; only ``num_keys`` values cross the wire.
* ``in_mapper`` — Algorithm 4: the per-key table is the scan carry; lifted
                  pairs are never materialized (O(num_keys) live values).

Algorithm 2 (the combiner that changes the value type) is rejected by
:func:`validate_combiner` — the machine-checked MapReduce contract.

Hardware adaptation (DESIGN.md §2): Hadoop's disk shuffle becomes an
``all_to_all``/``psum_scatter`` key re-partition; Hadoop's dynamic keys become
a static key space (hash-bucketed when open — the paper's own sketches are the
unbounded-key answer). Byte accounting reports both the MapReduce-equivalent
shuffle bytes (pairs x bytes, the paper's cost model) and the XLA-actual
collective bytes on this mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .monoid import Monoid, MonoidTypeError, Pytree, tree_fold
from .plan import Plan, _static_valid_count, execute_fold, plan_fold

STRATEGIES = ("naive", "combiner", "in_mapper")


@dataclasses.dataclass(frozen=True)
class ShuffleStats:
    """The paper's efficiency story in numbers (per strategy, whole job).

    intermediate_values: monoid values materialized map-side (Alg 1/3: one per
      record; Alg 4: only the table).
    shuffle_values: monoid values that cross the wire (the sort/shuffle cost).
    shuffle_bytes_mapreduce: shuffle_values x bytes(value) — the paper's model.
    shuffle_bytes_xla: bytes the XLA collective actually moves on this mesh
      (ring reduce-scatter for the dense table; all_gather for naive pairs).
    shuffle_algorithm: the planner's cost-model shuffle choice
      ('reduce_scatter' | 'allreduce'; '' when the job has no mesh combine).
    predicted_us: the plan's modeled wall time (local tier + collectives)
      under the active calibration.
    measured_us: an observed wall time set by the caller via
      :meth:`with_measured`, so modeled-vs-measured rides one record.
    overlap_modeled: the plan's modeled hidden fraction of DCN crossing time
      (0 for sync plans: nothing is pipelined, nothing can hide).
    overlap_measured: the observed hidden fraction, set via
      :meth:`with_measured` — a healthy step keeps it near the model; a
      straggling host shows up here as collapsing overlap before it shows
      up as a timeout (see runtime/fault_tolerance.py).
    dense_wire_bytes / lossy_wire_bytes: per-device DCN bytes of the dense
      sync crossing vs what the plan actually moves (equal unless ``lossy``).
    lossy: the compression annotation (``LossySpec.describe()``; '' = dense).
    """

    strategy: str
    num_records: int
    num_keys: int
    value_bytes: int
    intermediate_values: int
    shuffle_values: int
    shuffle_bytes_mapreduce: int
    shuffle_bytes_xla: int
    plan: str = ""               # the planner's tier chain (plan.describe())
    shuffle_algorithm: str = ""
    predicted_us: float = 0.0
    measured_us: Optional[float] = None
    overlap_modeled: float = 0.0
    overlap_measured: Optional[float] = None
    dense_wire_bytes: int = 0
    lossy_wire_bytes: int = 0
    lossy: str = ""

    def reduction_vs_naive(self) -> float:
        naive = self.num_records * self.value_bytes
        return naive / max(self.shuffle_bytes_mapreduce, 1)

    def with_measured(self, us: float, *,
                      overlap: Optional[float] = None) -> "ShuffleStats":
        """Attach an observed wall time (microseconds) — and optionally the
        observed hidden-overlap fraction — to compare against the model;
        benchmarks report the model error from this."""
        return dataclasses.replace(
            self, measured_us=float(us),
            overlap_measured=(self.overlap_measured if overlap is None
                              else float(overlap)))

    def model_error(self) -> Optional[float]:
        """measured/predicted ratio (None until both sides exist)."""
        if self.measured_us is None or self.predicted_us <= 0:
            return None
        return self.measured_us / self.predicted_us

    def compression_ratio(self) -> float:
        """dense/actual DCN bytes (1.0 when the crossing is dense)."""
        if self.lossy_wire_bytes <= 0:
            return 1.0
        return self.dense_wire_bytes / self.lossy_wire_bytes

    def overlap_collapse(self) -> Optional[float]:
        """modeled − measured overlap fraction: how much of the promised
        hiding did NOT happen (None until a measurement is attached; only
        meaningful for async plans, where overlap_modeled > 0)."""
        if self.overlap_measured is None:
            return None
        return self.overlap_modeled - self.overlap_measured


def fold_stats(plan: Plan, *, strategy: str = "fold") -> ShuffleStats:
    """:class:`ShuffleStats` for a planner-lowered FLAT fold (a gradient
    fold, a metrics fold) — every figure read off the :class:`Plan`,
    including the overlap and compression annotations.  This is the
    per-step record the serving/training loops hand to
    ``runtime.fault_tolerance`` and the benchmarks emit."""
    crossings = (plan.num_records if plan.local_tier.kind == "async" else 1)
    return ShuffleStats(
        strategy=strategy, num_records=plan.num_records,
        num_keys=plan.num_segments or 0, value_bytes=plan.value_bytes,
        intermediate_values=plan.num_records, shuffle_values=crossings,
        shuffle_bytes_mapreduce=crossings * plan.value_bytes,
        shuffle_bytes_xla=plan.collective_wire_bytes,
        plan=plan.describe(), shuffle_algorithm=plan.shuffle_algorithm or "",
        predicted_us=plan.predicted_us,
        overlap_modeled=plan.overlap_modeled,
        dense_wire_bytes=plan.dense_wire_bytes,
        lossy_wire_bytes=plan.lossy_wire_bytes,
        lossy=plan.lossy or "")


def validate_combiner(monoid: Monoid, example_value: Pytree,
                      combiner_fn: Optional[Callable[[Pytree, Pytree], Pytree]] = None) -> None:
    """The MapReduce combiner contract: combine must map M x M -> M.

    The paper's Algorithm 2 fails this check (its combiner turns an ``int``
    into a ``(sum, count)`` pair). We verify with ``eval_shape`` so no FLOPs
    are spent; raises :class:`MonoidTypeError` on violation.
    """
    fn = combiner_fn if combiner_fn is not None else monoid.combine
    out = jax.eval_shape(fn, example_value, example_value)
    s_in = jax.tree_util.tree_structure(example_value)
    s_out = jax.tree_util.tree_structure(out)
    if s_in != s_out:
        raise MonoidTypeError(
            f"combiner output structure {s_out} != input value structure {s_in}: "
            "a combiner may run zero, one, or many times, so its output type "
            "must equal its input type (paper, Algorithm 2)."
        )
    for li, lo in zip(jax.tree_util.tree_leaves(example_value), jax.tree_util.tree_leaves(out)):
        if jnp.shape(li) != lo.shape or jnp.result_type(li) != lo.dtype:
            raise MonoidTypeError(
                f"combiner changed leaf {jnp.shape(li)}/{jnp.result_type(li)} -> "
                f"{lo.shape}/{lo.dtype} (paper, Algorithm 2)."
            )


@dataclasses.dataclass(frozen=True)
class MapReduceJob:
    """A static-shaped MapReduce job over a fixed key space.

    mapper: record -> (key, raw_value); vmapped over the record axis.
    monoid: the intermediate-value monoid (lift applied to raw mapper output).
    num_keys: size of the key space (hash-bucket open key spaces).
    """

    mapper: Callable[[Pytree], Tuple[jnp.ndarray, Pytree]]
    monoid: Monoid
    num_keys: int

    # -- map side -------------------------------------------------------------
    def _map_records(self, records: Pytree) -> Tuple[jnp.ndarray, Pytree]:
        keys, raws = jax.vmap(self.mapper)(records)
        return keys.astype(jnp.int32), raws

    def _local_table_combiner(self, records: Pytree) -> Pytree:
        """Algorithm 3: materialize lifted pairs, then combiner-fold by key.

        The planner picks the tier (Pallas kernel / segment-ops / scan)."""
        keys, raws = self._map_records(records)
        return execute_fold(self.monoid, raws, segment_ids=keys,
                            num_segments=self.num_keys, lifted=False)

    def _local_table_in_mapper(self, records: Pytree) -> Pytree:
        """Algorithm 4: fold each record straight into the per-key table —
        the planner's scan tier with the lift fused into the scan step, so
        lifted pairs are never materialized."""
        keys, raws = self._map_records(records)
        return execute_fold(self.monoid, raws, segment_ids=keys,
                            num_segments=self.num_keys, layout="scan",
                            lifted=False)

    def _fold_pairs_into_table(self, keys: jnp.ndarray, lifted: Pytree) -> Pytree:
        return execute_fold(self.monoid, lifted, segment_ids=keys,
                            num_segments=self.num_keys)

    # -- single-host reference execution ---------------------------------------
    def run_local(self, records: Pytree, *, strategy: str = "in_mapper",
                  num_shards: int = 1, extract: bool = True) -> Pytree:
        """Reference execution with ``num_shards`` simulated mappers.

        Identical numerics to :meth:`run_sharded`; used by tests/benchmarks on
        one device. Records' leading axis must divide by num_shards.
        """
        if strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}")
        n = jax.tree_util.tree_leaves(records)[0].shape[0]
        assert n % num_shards == 0, (n, num_shards)
        sharded = jax.tree_util.tree_map(
            lambda x: x.reshape((num_shards, n // num_shards) + x.shape[1:]), records)

        if strategy == "naive":
            # every lifted pair survives to the "reduce" side
            keys, raws = jax.vmap(self._map_records)(sharded)
            lifted = jax.vmap(jax.vmap(self.monoid.lift))(raws)
            flat_keys = keys.reshape((n,))
            flat = jax.tree_util.tree_map(
                lambda x: x.reshape((n,) + x.shape[2:]), lifted)
            table = self._fold_pairs_into_table(flat_keys, flat)
        else:
            local = self._local_table_combiner if strategy == "combiner" \
                else self._local_table_in_mapper
            tables = jax.vmap(local)(sharded)              # (shards, K, ...)
            table = tree_fold(self.monoid, tables, axis=0)
        return self._finish(table, extract)

    # -- mesh execution ---------------------------------------------------------
    def run_sharded(self, records: Pytree, mesh: jax.sharding.Mesh, *,
                    axis_name: str = "data", strategy: str = "in_mapper",
                    extract: bool = True) -> Pytree:
        """shard_map execution: local phase on each device, monoid shuffle.

        records: globally-batched pytree, leading axis divisible by the axis
        size; each device runs the map+combine phase on its shard, then the
        dense key table is combined across devices with whatever shuffle the
        PLAN chose (``Plan.shuffle_algorithm`` — reduce-scatter + all-gather
        when the cost model prefers it, allreduce otherwise; this method
        makes no selection of its own):

          naive     -> all pairs cross the wire (all_gather), receivers fold
          combiner / in_mapper -> the plan's shuffle of the dense table

        The result is the full (num_keys, ...) extracted table, replicated.
        """
        from ..dist.collectives import combine_keyed_table

        if strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}")
        P = mesh.shape[axis_name]
        spec = jax.sharding.PartitionSpec(axis_name)
        nospec = jax.sharding.PartitionSpec()
        plan = self.plan(records, strategy=strategy, num_shards=P,
                         axis_name=axis_name)
        shuffle = plan.shuffle_algorithm or "allreduce"

        def shard_body(recs):
            if strategy == "naive":
                keys, raws = self._map_records(recs)
                lifted = jax.vmap(self.monoid.lift)(raws)
                all_keys = jax.lax.all_gather(keys, axis_name, axis=0, tiled=True)
                all_vals = jax.tree_util.tree_map(
                    lambda v: jax.lax.all_gather(v, axis_name, axis=0, tiled=True),
                    lifted)
                table = self._fold_pairs_into_table(all_keys, all_vals)
            else:
                local = self._local_table_combiner if strategy == "combiner" \
                    else self._local_table_in_mapper
                table = combine_keyed_table(self.monoid, local(recs),
                                            axis_name, algorithm=shuffle)
            return table

        in_specs = (jax.tree_util.tree_map(lambda _: spec, records),)
        fn = jax.shard_map(shard_body, mesh=mesh,
                           in_specs=in_specs, out_specs=nospec,
                           check_vma=False)
        table = fn(records)
        return self._finish(table, extract)

    def _finish(self, table: Pytree, extract: bool) -> Pytree:
        if not extract:
            return table
        return jax.vmap(self.monoid.extract)(table)

    # -- accounting --------------------------------------------------------------
    def plan(self, records: Pytree, *, strategy: str,
             num_shards: int, valid_mask=None,
             axis_name: str = "shard") -> Plan:
        """The execution plan for this job's per-shard fold + shuffle.

        The plan is built from ShapeDtypeStructs (no FLOPs): one shard's
        lifted pairs, keyed by ``num_keys``, combined across the
        ``axis_name`` mesh axis of size ``num_shards`` (pass the real axis
        name so :meth:`run_sharded` executes exactly this plan).
        strategy='naive' models Algorithm 1 (``pre_combine=False``: raw
        pairs cross the wire un-combined); 'combiner'/'in_mapper' differ
        only in the local tier.

        ``valid_mask`` (one bool per record, whole job) marks padding rows
        that never become pairs; the per-shard plan uses shard 0's slice as
        representative for the masked byte model.
        """
        if strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}")
        n = jax.tree_util.tree_leaves(records)[0].shape[0]
        local_n = max(1, n // num_shards)
        one_rec = jax.tree_util.tree_map(lambda x: x[0], records)
        _, raw_shape = jax.eval_shape(self.mapper, one_rec)
        value_shape = jax.eval_shape(self.monoid.lift, raw_shape)
        pairs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((local_n,) + s.shape, s.dtype),
            value_shape)
        seg = jax.ShapeDtypeStruct((local_n,), jnp.int32)
        shard_mask = None
        if valid_mask is not None:
            if isinstance(valid_mask, jax.ShapeDtypeStruct):
                # shape-only planning: the mask stays abstract per shard
                shard_mask = jax.ShapeDtypeStruct((local_n,), jnp.bool_)
            else:
                shard_mask = jnp.asarray(valid_mask, jnp.bool_)[:local_n]
        return plan_fold(
            self.monoid, pairs, segment_ids=seg, num_segments=self.num_keys,
            valid_mask=shard_mask,
            mesh_axes=(axis_name,), axis_sizes={axis_name: num_shards},
            layout="scan" if strategy == "in_mapper" else "auto",
            pre_combine=strategy != "naive")

    def stats(self, records: Pytree, *, strategy: str, num_shards: int,
              valid_mask=None) -> ShuffleStats:
        """The paper's cost model for this job on ``num_shards`` mappers —
        every byte figure is read off the execution plan.  With a ragged
        ``valid_mask`` only valid rows become pairs, so only they are
        counted as intermediate/shuffled values."""
        n = jax.tree_util.tree_leaves(records)[0].shape[0]
        plan = self.plan(records, strategy=strategy, num_shards=num_shards,
                         valid_mask=valid_mask)
        n_valid = _static_valid_count(valid_mask)
        if n_valid is None:       # no mask, or abstract: count every row
            n_valid = n
        vbytes = plan.value_bytes
        table_values = self.num_keys * num_shards

        if strategy == "naive":
            inter, shuffled = n_valid, n_valid
        elif strategy == "combiner":
            inter, shuffled = n_valid + table_values, table_values
        else:  # in_mapper: only the table is ever live
            inter, shuffled = table_values, table_values
        return ShuffleStats(
            strategy=strategy, num_records=n, num_keys=self.num_keys,
            value_bytes=vbytes, intermediate_values=inter,
            shuffle_values=shuffled,
            shuffle_bytes_mapreduce=shuffled * vbytes,
            shuffle_bytes_xla=plan.collective_wire_bytes,
            plan=plan.describe(),
            shuffle_algorithm=plan.shuffle_algorithm or "",
            predicted_us=plan.predicted_us,
        )


# ---------------------------------------------------------------------------
# The paper's running example: average of values by key (Algorithms 1/3/4)
# ---------------------------------------------------------------------------

def average_by_key_job(num_keys: int) -> MapReduceJob:
    """Mean-by-key: the paper's running example with the (sum, count) monoid."""
    from . import monoids

    def mapper(record):
        return record["key"], record["value"]

    return MapReduceJob(mapper=mapper, monoid=monoids.mean, num_keys=num_keys)


def algorithm2_combiner(t_and_r, _ignored):
    """The paper's ILLEGAL Algorithm 2 combiner: int values -> (sum, count).

    Provided so the test/benchmark can show the engine rejecting it.
    """
    return (t_and_r, jnp.ones((), jnp.int32))


def word_count_job(vocab: int) -> MapReduceJob:
    """The canonical MapReduce hello-world as a monoid job."""
    from . import monoids

    def mapper(token):
        return token, jnp.ones((), jnp.int32)

    return MapReduceJob(mapper=mapper, monoid=monoids.sum_, num_keys=vocab)


def cooccurrence_stripes_job(vocab: int, window: int) -> MapReduceJob:
    """Algorithm 5 (stripes): records are token windows; key = center word,
    value = the stripe (dense count vector over the vocab)."""
    from . import monoids

    def mapper(win):
        center = window  # records are (2*window+1,) token windows
        w = win[center]
        neigh_idx = jnp.concatenate([jnp.arange(window), jnp.arange(window + 1, 2 * window + 1)])
        stripe = jnp.zeros((vocab,), jnp.int32).at[win[neigh_idx]].add(1)
        return w, stripe

    return MapReduceJob(mapper=mapper, monoid=monoids.stripes, num_keys=vocab)
