"""The unified monoid execution planner — ONE lowering path for every fold.

The paper's point is that once an intermediate value is a monoid, the
*framework* — not the caller — may re-bracket and relocate the reduction
(combiner, in-mapper combining, hierarchical aggregation).  This module is
that freedom given a single entry point: :func:`execute_fold` lowers any
fold — flat or keyed, local or cross-mesh — to a tiered plan:

  tier 1  kernel      a registered Pallas lowering (kernels/segment_fold.py's
                      semiring kernel) when the monoid has one,
  tier 2  segment-ops ``jax.ops.segment_*`` for the monoids XLA reduces
                      natively, or the generic serial scan / tree fold that
                      works for ANY monoid,
  tier 3  collective  hierarchical ICI-first-then-DCN mesh combine via
                      ``dist/collectives.py`` (the rack-aware combiner tree).

:func:`plan_fold` is the pure cost model behind it: it reports the chosen
tier per stage, the predicted shuffle/collective bytes, AND the predicted
wall time per tier from the calibrated coefficients of
:mod:`repro.core.calibration` — so ``layout='auto'`` is an argmin over
predicted microseconds (backend detection is only the feasibility filter),
the reduce-scatter-vs-allreduce shuffle choice is made here rather than in
callers, and ``mapreduce.ShuffleStats`` is derived from the plan rather
than ad-hoc accounting.  Planning works on concrete arrays or
ShapeDtypeStructs alike.

Kernel lowerings are registered on :class:`~repro.core.monoid.Monoid` by
name (see ``register_kernel_lowering``); the additive and max-plus zoo
monoids get leaf-wise semiring lowerings below.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .monoid import (KernelLowering, Monoid, Pytree, register_kernel_lowering,
                     scan_fold, tree_fold)
from .aggregation import _PMAX_LIKE, _PMIN_LIKE, _PSUM_LIKE, tree_bytes
from .calibration import Calibration, get_calibration, pipeline_exposed_us

LAYOUTS = ("auto", "kernel", "segment", "scan", "tree", "async")

# layout spelling (user-facing) -> calibration tier kind (TierPlan.kind).
# 'async' is absent on purpose: it is a whole-plan shape (fused local +
# pipelined crossings), not a local tier the per-record model prices.
_LAYOUT_TIER_KIND = {"kernel": "kernel", "segment": "segment_ops",
                     "scan": "scan", "tree": "tree"}

# TierPlan.kind values that are collective (shuffle) stages, not local folds
_COLLECTIVE_KINDS = ("gather_pairs", "allreduce", "reduce_scatter")

# monoids XLA reduces natively with a segment primitive (tier 2, fast path)
_SEGMENT_OPS: Mapping[str, Callable] = {
    "sum": jax.ops.segment_sum,
    "count": jax.ops.segment_sum,
    "mean": jax.ops.segment_sum,   # applied leaf-wise to (sum, count)
    "max": jax.ops.segment_max,
    "min": jax.ops.segment_min,
    "bitwise_or": jax.ops.segment_max,   # 0/1 bitmaps: OR == max
}


# ---------------------------------------------------------------------------
# kernel lowerings for the zoo — leaf-wise semiring application
# ---------------------------------------------------------------------------

def _semiring_lowering(semiring: str) -> KernelLowering:
    """Leaf-wise lowering onto the semiring-parameterized Pallas kernel.

    Each leaf (N, ...) is flattened to (N, D), folded by key on the MXU/VPU,
    and reshaped to (num_segments, ...).  Exact integer leaves round-trip to
    their dtype (kernels/segment_fold.py handles the cast-back).
    """

    def lower(values: Pytree, seg_ids: jnp.ndarray, num_segments: int, *,
              block_n: int = 512, valid_mask: Optional[jnp.ndarray] = None,
              interpret: Optional[bool] = None) -> Pytree:
        from ..kernels.segment_fold import segment_fold_pallas

        def per_leaf(v):
            v = jnp.asarray(v)
            flat = v.reshape((v.shape[0], -1))
            out = segment_fold_pallas(flat, seg_ids, num_segments,
                                      semiring=semiring, block_n=block_n,
                                      valid_mask=valid_mask,
                                      interpret=interpret)
            return out.reshape((num_segments,) + v.shape[1:])

        return jax.tree_util.tree_map(per_leaf, values)

    return KernelLowering(semiring=semiring, fn=lower)


def _mean_pair_lowering() -> KernelLowering:
    """Fused lowering for mean's (sum, count) pair: the count column rides
    the same one-hot matmul as the sums (ONE kernel launch, the paper's
    running example), falling back to leaf-wise for pytree-valued sums."""
    leafwise = _semiring_lowering("sum").fn

    def lower(values: Pytree, seg_ids: jnp.ndarray, num_segments: int, *,
              block_n: int = 512, valid_mask: Optional[jnp.ndarray] = None,
              interpret: Optional[bool] = None) -> Pytree:
        from ..kernels.segment_fold import segment_fold_pallas

        s, c = values
        s_leaves = jax.tree_util.tree_leaves(s)
        if len(s_leaves) != 1 or jnp.ndim(c) != 1:
            return leafwise(values, seg_ids, num_segments, block_n=block_n,
                            valid_mask=valid_mask, interpret=interpret)
        (sv,) = s_leaves
        sv = jnp.asarray(sv)
        flat = jnp.concatenate(
            [sv.reshape((sv.shape[0], -1)).astype(jnp.float32),
             jnp.asarray(c).reshape((-1, 1)).astype(jnp.float32)], axis=1)
        out = segment_fold_pallas(flat, seg_ids, num_segments,
                                  semiring="sum", block_n=block_n,
                                  valid_mask=valid_mask,
                                  interpret=interpret)
        sums = out[:, :-1].reshape((num_segments,) + sv.shape[1:])
        if jnp.issubdtype(sv.dtype, jnp.integer):
            sums = sums.astype(sv.dtype)
        counts = out[:, -1]
        if jnp.issubdtype(jnp.asarray(c).dtype, jnp.integer):
            counts = counts.astype(jnp.asarray(c).dtype)
        treedef = jax.tree_util.tree_structure(s)
        return (jax.tree_util.tree_unflatten(treedef, [sums]), counts)

    return KernelLowering(semiring="sum", fn=lower)


# The additive family rides the MXU one-hot matmul; the max-plus family the
# VPU masked reduce.  bitwise_or qualifies because the sketch monoids keep
# 0/1 uint8 bitmaps, where OR == max (see aggregation.monoid_allreduce).
# (monoids.stripes is an alias of sum_ — Monoid.name 'sum' — so the stripes
# fold rides the 'sum' registration; no separate entry needed.)
for _name in ("sum", "count"):
    register_kernel_lowering(_name, _semiring_lowering("sum"))
register_kernel_lowering("mean", _mean_pair_lowering())
register_kernel_lowering("max", _semiring_lowering("max"))
register_kernel_lowering("bitwise_or", _semiring_lowering("max"))
register_kernel_lowering("min", _semiring_lowering("min"))


# ---------------------------------------------------------------------------
# the plan — tiers + predicted bytes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierPlan:
    """One stage of a lowered fold.

    kind: 'kernel' | 'segment_ops' | 'scan' | 'tree' | 'gather_pairs' |
          'allreduce' | 'reduce_scatter'.
    wire_bytes: predicted bytes this stage puts on the wire, summed over the
      participants of one reduction group (0 for on-device stages).
    predicted_us: modeled wall time of this stage under the active
      calibration (0 when the model has nothing to say, e.g. unknown axis
      size).
    candidate_us: the (candidate, predicted_us) table the planner chose
      from — layout names for the local tier, shuffle algorithms for a
      collective tier.  Empty for stages with no choice.
    """

    kind: str
    detail: str
    out_bytes: int
    wire_bytes: int = 0
    predicted_us: float = 0.0
    candidate_us: Tuple[Tuple[str, float], ...] = ()


@dataclasses.dataclass(frozen=True)
class Plan:
    """A lowered fold: local tier(s) followed by collective tier(s).

    ``num_valid`` is the statically-known count of rows a ``valid_mask``
    keeps (None when no mask was given or the mask is abstract/traced) —
    ragged folds shuffle only valid rows, and the byte model reflects that.
    """

    monoid: Monoid
    tiers: Tuple[TierPlan, ...]
    num_records: int
    num_segments: Optional[int]
    value_bytes: int          # bytes of ONE lifted monoid value
    out_bytes: int            # bytes of the final local result (table/value)
    num_valid: Optional[int] = None
    # -- overlap / compression annotations (flat mesh folds) ----------------
    lossy: Optional[str] = None        # LossySpec.describe() when annotated
    overlap_modeled: float = 0.0       # modeled hidden fraction of DCN time
    dense_wire_bytes: int = 0          # per-device DCN bytes of a dense sync
                                       #   crossing (0: no DCN axis planned)
    lossy_wire_bytes: int = 0          # per-device DCN bytes actually planned
                                       #   (== dense_wire_bytes when not lossy)
    plan_candidate_us: Tuple[Tuple[str, float], ...] = ()
                                       # whole-plan (sync vs async) argmin
                                       #   table when both shapes were priced

    @property
    def local_tier(self) -> TierPlan:
        return next(t for t in self.tiers
                    if t.kind not in _COLLECTIVE_KINDS)

    @property
    def collective_wire_bytes(self) -> int:
        return sum(t.wire_bytes for t in self.tiers)

    @property
    def predicted_us(self) -> float:
        """Modeled wall time of the whole plan (local + collectives)."""
        return float(sum(t.predicted_us for t in self.tiers))

    @property
    def candidate_us(self) -> Mapping[str, float]:
        """Predicted microseconds per feasible local-tier layout — the table
        ``layout='auto'`` argmins over."""
        return dict(self.local_tier.candidate_us)

    @property
    def shuffle_algorithm(self) -> Optional[str]:
        """'reduce_scatter' | 'allreduce' for the first collective stage
        (None when the plan has no collective stage) — what
        ``mapreduce.run_sharded`` executes instead of choosing itself."""
        for t in self.tiers:
            if t.kind in ("allreduce", "reduce_scatter"):
                return t.kind
        return None

    @property
    def shuffle_candidate_us(self) -> Mapping[str, float]:
        """Predicted microseconds per shuffle algorithm on the first
        collective axis (empty when there is none or its size is unknown)."""
        for t in self.tiers:
            if t.kind in ("allreduce", "reduce_scatter"):
                return dict(t.candidate_us)
        return {}

    def describe(self) -> str:
        parts = []
        for t in self.tiers:
            us = f" ~{t.predicted_us:.1f}us" if t.predicted_us > 0 else ""
            parts.append(f"{t.kind}[{t.detail}{us}]")
        s = " -> ".join(parts)
        if self.lossy:
            s += (f" [lossy={self.lossy}: dcn {self.lossy_wire_bytes}B"
                  f" vs {self.dense_wire_bytes}B dense]")
        if self.overlap_modeled > 0.0:
            s += f" [overlap modeled {self.overlap_modeled:.0%}]"
        return s


def collective_algorithm(m: Monoid) -> str:
    """'ring' when the monoid lowers to a psum/pmax/pmin-family collective
    (see aggregation.monoid_allreduce), 'gather' for the generic fallback."""
    name = m.name
    if (name in _PSUM_LIKE or name in _PMAX_LIKE or name in _PMIN_LIKE
            or name in ("mean", "logsumexp", "attn_state")
            or name.startswith("hll") or name.startswith("cms")):
        return "ring"
    return "gather"


def collective_wire_bytes(nbytes: int, axis_size: int, algorithm: str) -> int:
    """Total wire bytes across one reduction group of ``axis_size`` devices."""
    if axis_size <= 1:
        return 0
    if algorithm in ("ring", "reduce_scatter"):
        # ring allreduce decomposes into the same two phases the explicit
        # reduce-scatter + all-gather spells out: 2(P-1)/P x nbytes each
        return int(2 * nbytes * (axis_size - 1))
    if algorithm == "gather":     # every device replicates its value P-1 times
        return int(nbytes * (axis_size - 1) * axis_size)
    raise ValueError(algorithm)


def _per_device_shuffle_bytes(nbytes: int, axis_size: int, shuffle_kind: str,
                              allreduce_algo: str) -> float:
    """Wire bytes ONE device moves for a table shuffle — the quantity the
    link-time model prices.  reduce_scatter scatters then gathers 1/P shards
    (2(P-1)/P x nbytes) for any monoid; allreduce matches that for the
    psum/pmax-family ('ring') but degrades to a full (P-1) x nbytes gather
    for generic monoids."""
    if axis_size <= 1:
        return 0.0
    if shuffle_kind == "reduce_scatter" or allreduce_algo == "ring":
        return 2.0 * nbytes * (axis_size - 1) / axis_size
    return float(nbytes) * (axis_size - 1)


def _split_ici_dcn(mesh_axes: Sequence[Any]) -> Tuple[Tuple, Tuple]:
    # delegate to dist: planning order must match execution order exactly
    from ..dist.collectives import split_axis_names
    return split_axis_names(mesh_axes)


def _leading_dim(values: Pytree) -> int:
    return jax.tree_util.tree_leaves(values)[0].shape[0]


def _one_slice(values: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda v: jax.ShapeDtypeStruct(v.shape[1:], v.dtype), values)


def _lifted_value_shape(m: Monoid, values: Pytree, lifted: bool,
                        map_fn: Optional[Callable]) -> Pytree:
    """Shape/dtype pytree of ONE lifted monoid value (no FLOPs spent)."""
    one = _one_slice(values)
    if map_fn is not None:
        return jax.eval_shape(lambda x: m.lift(map_fn(x)), one)
    if not lifted:
        return jax.eval_shape(m.lift, one)
    return one


def _static_valid_count(valid_mask) -> Optional[int]:
    """Number of True rows when the mask is concrete; None when abstract
    (ShapeDtypeStruct at plan time, or a tracer inside jit)."""
    if valid_mask is None or isinstance(valid_mask, jax.ShapeDtypeStruct):
        return None
    try:
        return int(jnp.sum(jnp.asarray(valid_mask, jnp.bool_)))
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError, TypeError):
        return None


def _check_valid_mask(valid_mask, n: int) -> None:
    shape = getattr(valid_mask, "shape", None)
    if shape is not None and tuple(shape) != (n,):
        raise ValueError(
            f"valid_mask must be one flag per record, shape ({n},); got "
            f"shape {tuple(shape)}")


def _mask_rows_to_identity(m: Monoid, values: Pytree,
                           valid_mask: jnp.ndarray) -> Pytree:
    """Replace invalid rows of a LIFTED batch with the monoid identity, so
    they are no-ops under combine — the generic ragged lowering that works
    for ANY monoid (scan/tree tiers)."""
    mask = jnp.asarray(valid_mask, jnp.bool_)
    one = m.identity_like(jax.tree_util.tree_map(lambda v: v[0], values))
    return jax.tree_util.tree_map(
        lambda v, i: jnp.where(
            mask.reshape(mask.shape + (1,) * (jnp.ndim(v) - 1)), v,
            jnp.asarray(i, jnp.asarray(v).dtype)),
        values, one)


def _mask_segment_ids(segment_ids: jnp.ndarray, valid_mask,
                      num_segments: int) -> jnp.ndarray:
    """Route invalid rows to the out-of-range id ``num_segments`` — dropped
    by XLA scatters (jax.ops.segment_*) and by the Pallas kernel's one-hot,
    exactly like its block padding."""
    if valid_mask is None:
        return segment_ids
    return jnp.where(jnp.asarray(valid_mask, jnp.bool_), segment_ids,
                     num_segments)


def _kernel_infeasible_reason(m: Monoid, value_shape: Pytree) -> Optional[str]:
    """Why the kernel tier cannot lower this fold — None when it can.

    The returned text names the offending leaf (tree path) and its dtype,
    so a forced ``layout='kernel'`` fails at PLAN time with an actionable
    message instead of deep inside the Pallas lowering."""
    if m.kernel_lowering() is None:
        return (f"monoid {m.name!r} has no registered Pallas kernel lowering "
                "(see register_kernel_lowering)")
    leaves, _ = jax.tree_util.tree_flatten_with_path(value_shape)
    for path, leaf in leaves:
        if not (jnp.issubdtype(leaf.dtype, jnp.floating)
                or jnp.issubdtype(leaf.dtype, jnp.integer)):
            where = jax.tree_util.keystr(path) or "<value>"
            return (f"value leaf {where!r} has dtype "
                    f"{jnp.dtype(leaf.dtype).name}, which the Pallas "
                    "segment-fold kernel cannot lower (float/int leaves only)")
    return None


def _kernel_compatible(m: Monoid, value_shape: Pytree) -> bool:
    return _kernel_infeasible_reason(m, value_shape) is None


def _kernel_exact(value_shape: Pytree, num_records: int) -> bool:
    """Whether the kernel's float32 accumulator is exact for these inputs.

    Integer leaves are accumulated in float32; that is exact only while the
    per-key running total stays below 2**24.  We cannot see magnitudes at
    plan time, so ``layout='auto'`` only keeps integer inputs on the kernel
    tier when even the worst case (every record at the dtype's extreme, all
    landing in one key) fits — narrow dtypes (8/16-bit bitmaps and counts)
    pass for reasonable batches; 32-bit-and-wider integers always down-tier
    to the exact segment-ops path.  Forcing ``layout='kernel'`` bypasses
    this — the caller asserts their magnitudes fit.
    """
    for leaf in jax.tree_util.tree_leaves(value_shape):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            info = jnp.iinfo(leaf.dtype)
            # extreme magnitude: unsigned dtypes have info.min == 0, so the
            # bound must come from info.max there
            extreme = max(abs(int(info.min)), int(info.max))
            if extreme * max(num_records, 1) >= 2 ** 24:
                return False
    return True


def _link_domain(ax: Any) -> str:
    """'dcn' for axes wired over DCN (dist.collectives.DCN_AXIS_NAMES),
    'ici' otherwise — the calibration's two link classes."""
    _, dcn = _split_ici_dcn((ax,))
    return "dcn" if dcn else "ici"


def _plan_collective_tier(calib: Calibration, label: str, ax: Any,
                          P: Optional[int], nbytes: int,
                          num_segments: Optional[int],
                          allreduce_algo: str) -> TierPlan:
    """One collective stage, its shuffle algorithm chosen by predicted cost.

    Candidates: 'reduce_scatter' (keyed tables whose key count divides the
    axis size — each device reduces one key shard, then all-gathers: the
    MapReduce shuffle proper) and 'allreduce' (ring for the psum/pmax
    family, gather + on-device fold for generic monoids).  Argmin over the
    calibrated link model; a predicted tie prefers reduce_scatter because
    it distributes the per-key reduce work across the group.  An unknown
    or trivial axis size plans a 0-cost allreduce (today's behavior).
    """
    candidates = ["allreduce"]
    if num_segments is not None and P and P > 1 and num_segments % P == 0:
        candidates.insert(0, "reduce_scatter")   # ties prefer reduce_scatter
    if not P or P <= 1:
        wire = collective_wire_bytes(nbytes, P, allreduce_algo) if P else 0
        return TierPlan("allreduce",
                        f"{label}:{ax} {allreduce_algo}"
                        + ("" if P else " (size unknown)"),
                        nbytes, wire)
    cand_us = tuple(
        (kind, calib.predict_link_us(
            label, _per_device_shuffle_bytes(nbytes, P, kind, allreduce_algo)))
        for kind in candidates)
    costs = dict(cand_us)
    kind = min(candidates, key=costs.get)
    if kind == "reduce_scatter":
        return TierPlan("reduce_scatter",
                        f"{label}:{ax} reduce_scatter+all_gather",
                        nbytes, collective_wire_bytes(nbytes, P, kind),
                        predicted_us=costs[kind], candidate_us=cand_us)
    return TierPlan("allreduce", f"{label}:{ax} {allreduce_algo}",
                    nbytes, collective_wire_bytes(nbytes, P, allreduce_algo),
                    predicted_us=costs[kind], candidate_us=cand_us)


def _plan_lossy_dcn_tier(calib: Calibration, ax: Any, P: Optional[int],
                         comp_bytes: int, dense_bytes: int,
                         spec) -> TierPlan:
    """The DCN crossing of a ``lossy=`` fold: compressed messages gathered
    and combined on-device (concat + scatter-add / dequant-sum — the lossy
    monoid's exact regime), priced at the COMPRESSED bytes."""
    detail = f"dcn:{ax} lossy[{spec.describe()}] {comp_bytes}B/{dense_bytes}B"
    if not P or P <= 1:
        return TierPlan("allreduce", detail + ("" if P else " (size unknown)"),
                        comp_bytes, 0)
    per_dev = float(comp_bytes) * (P - 1)    # gather: each message replicated
    return TierPlan("allreduce", detail, comp_bytes,
                    int(comp_bytes * (P - 1) * P),
                    predicted_us=calib.predict_link_us("dcn", per_dev))


def _plan_async_tier(calib: Calibration, *, n: int, value_bytes: int,
                     out_bytes: int, local_us_total: float,
                     ici: Sequence[Any], dcn: Sequence[Any],
                     sizes: Mapping[Any, int], spec, comp_bytes: int,
                     algo: str) -> Tuple[TierPlan, float, float]:
    """Price the double-buffered shape: n ICI-combined partials, the DCN
    crossing of partial i pipelined against the compute of partial i+1.

    Per-microbatch ICI combines and the compute slot cannot hide anything
    (they ARE the foreground work); of the n DCN crossings, n-1 are
    pipelined and the epilogue is structurally exposed.  How much of the
    pipelined in-flight time is actually hidden is the platform's measured
    ``overlap_frac`` (0 where the compiler serializes collectives against
    compute — CPU — so 'auto' correctly declines the shape there).

    Returns (tier, total_us, modeled hidden fraction of DCN time).
    """
    ici_us, ici_wire = 0.0, 0
    for ax in ici:
        P = sizes.get(ax)
        if P and P > 1:
            ici_us += calib.predict_link_us(
                "ici", _per_device_shuffle_bytes(value_bytes, P,
                                                 "allreduce", algo))
            ici_wire += collective_wire_bytes(value_bytes, P, algo)
    cross_us, dcn_wire = 0.0, 0
    for ax in dcn:
        P = sizes.get(ax)
        if P and P > 1:
            if spec is not None:
                cross_us += calib.predict_link_us(
                    "dcn", float(comp_bytes) * (P - 1))
                dcn_wire += comp_bytes * (P - 1) * P
            else:
                cross_us += calib.predict_link_us(
                    "dcn", _per_device_shuffle_bytes(value_bytes, P,
                                                     "allreduce", algo))
                dcn_wire += collective_wire_bytes(value_bytes, P, algo)
    slot_us = local_us_total / n + ici_us
    exposed, hideable = pipeline_exposed_us(
        num_crossings=n, slot_us=slot_us, cross_us=cross_us)
    ofrac = min(max(calib.link_coeff("dcn").overlap_frac, 0.0), 1.0)
    hidden = hideable * ofrac
    total_cross = n * cross_us
    total = local_us_total + n * ici_us + total_cross - hidden
    modeled = hidden / total_cross if total_cross > 0.0 else 0.0
    detail = (f"double-buffered x{n} microbatch crossings"
              + (f" lossy[{spec.describe()}]" if spec is not None else "")
              + f", modeled overlap {modeled:.0%}")
    tier = TierPlan("async", detail, out_bytes,
                    n * (ici_wire + dcn_wire), predicted_us=total)
    return tier, total, modeled


def plan_fold(m: Monoid, values: Pytree, *, segment_ids=None,
              num_segments: Optional[int] = None,
              valid_mask=None,
              mesh_axes: Optional[Sequence[Any]] = None,
              layout: str = "auto", lifted: bool = True,
              map_fn: Optional[Callable] = None,
              mesh: Optional[jax.sharding.Mesh] = None,
              axis_sizes: Optional[Mapping[Any, int]] = None,
              pre_combine: bool = True, block_n: int = 512,
              lossy=None,
              calibration: Optional[Calibration] = None) -> Plan:
    """Lower a fold to a tiered :class:`Plan` without executing it.

    ``values`` may be concrete arrays or ShapeDtypeStructs — planning costs
    no FLOPs.  ``pre_combine=False`` models the paper's Algorithm 1 (no
    combiner: raw pairs cross the wire, receivers fold) purely for byte
    accounting; :func:`execute_fold` refuses to run such plans.

    ``layout='auto'`` is an argmin over predicted microseconds from the
    active :class:`~repro.core.calibration.Calibration` (override with
    ``calibration=``): backend detection and dtype checks only decide which
    tiers are FEASIBLE; the calibrated time model decides which feasible
    tier wins.  The same model chooses reduce-scatter vs allreduce per
    collective axis (``Plan.shuffle_algorithm``).  A forced ``layout=``
    that is infeasible for the inputs raises at plan time with the
    offending leaf dtype named.

    ``valid_mask`` (one bool per record) makes the fold ragged: invalid rows
    contribute the monoid identity on every tier, and — when the mask is
    concrete — only valid rows count toward the shuffle byte model
    (``Plan.num_valid``).  This is how padded batches and packed sequences
    fold without materializing a rectangle of real records.

    ``layout='async'`` plans the double-buffered shape of
    :func:`repro.dist.collectives.async_microbatch_fold` — the DCN crossing
    of record *i*'s ICI-combined partial pipelined against record *i+1*'s
    compute.  It is a flat-fold layout and needs ``mesh_axes=``; under
    ``layout='auto'`` the shape participates in the argmin (priced with the
    calibrated ``overlap_frac`` of the DCN link), with a predicted tie going
    to the sync shape.

    ``lossy=`` (a :class:`repro.optim.compress.LossySpec` or its string
    spelling, e.g. ``"topk:0.01"``) annotates a flat additive fold: the DCN
    crossing moves the compressed representation instead of dense floats,
    and the byte/time model prices the compressed bytes
    (``Plan.lossy_wire_bytes`` vs ``Plan.dense_wire_bytes``).

    Axis sizes for collective byte prediction come from ``mesh`` or
    ``axis_sizes``; unknown sizes predict 0 wire bytes.
    """
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}")
    keyed = segment_ids is not None
    if keyed and num_segments is None:
        raise ValueError(
            "segment_ids= was passed without num_segments=: a keyed fold "
            "returns a static (num_segments, ...) table, so pass the key-"
            "space size as num_segments=")
    spec = None
    if lossy is not None:
        from ..optim.compress import LossySpec  # lazy: optim imports core
        spec = LossySpec.parse(lossy)
        if keyed:
            raise ValueError(
                "lossy= compression applies to flat (gradient) folds; keyed "
                "tables cross the wire dense")
        if m.name != "sum":
            raise ValueError(
                f"lossy= compression needs an additive fold; got monoid "
                f"{m.name!r}")
    if layout == "async":
        if keyed:
            raise ValueError(
                "layout='async' overlaps the DCN crossing of a flat "
                "microbatch fold; keyed folds use kernel/segment/scan")
        if not mesh_axes:
            raise ValueError(
                "layout='async' needs mesh_axes= — without a mesh there is "
                "no crossing to overlap")

    n = _leading_dim(values)
    if valid_mask is not None:
        _check_valid_mask(valid_mask, n)
    num_valid = _static_valid_count(valid_mask)
    n_model = n if num_valid is None else num_valid   # rows in the byte model
    value_shape = _lifted_value_shape(m, values, lifted, map_fn)
    vbytes = tree_bytes(value_shape)
    out_bytes = (num_segments * vbytes) if keyed else vbytes
    masked = " +mask" if valid_mask is not None else ""

    calib = calibration if calibration is not None else get_calibration()
    leaves = jax.tree_util.tree_leaves(value_shape)
    dtype_key = jnp.dtype(leaves[0].dtype).name if leaves else "*"

    def local_us(layout_name: str) -> float:
        # every tier touches all n rows (masked rows still flow through the
        # kernel/scatter/scan); only the SHUFFLE byte model is ragged-aware
        return calib.predict_local_us(
            _LAYOUT_TIER_KIND[layout_name], monoid=m.name, dtype=dtype_key,
            num_records=n, record_bytes=vbytes)

    # -- local tier: feasibility filter, then argmin over predicted cost ----
    if keyed:
        if layout == "tree":
            raise ValueError("layout='tree' is a flat-fold layout; keyed "
                             "folds use kernel/segment/scan")
        kernel_reason = _kernel_infeasible_reason(m, value_shape)
        candidates = []
        # feasibility only: the kernel tier needs a registered lowering with
        # compatible dtypes, an exact accumulator, and the TPU backend —
        # WHICH feasible tier runs is the cost model's call below
        if (kernel_reason is None and _kernel_exact(value_shape, n_model)
                and jax.default_backend() == "tpu"):
            candidates.append("kernel")
        if m.name in _SEGMENT_OPS:
            candidates.append("segment")
        candidates.append("scan")
        shown = candidates + ([layout] if layout not in ("auto", *candidates)
                              else [])
        candidate_us = tuple((c, local_us(c)) for c in shown)
        costs = dict(candidate_us)
        kind = (min(candidates, key=costs.get) if layout == "auto"
                else layout)
        if kind == "kernel":
            if kernel_reason is not None:
                raise ValueError(
                    f"layout='kernel' was requested but is infeasible: "
                    f"{kernel_reason}. Use layout='segment' or "
                    "layout='scan', or leave layout='auto' to let the cost "
                    "model pick among feasible tiers.")
            low = m.kernel_lowering()
            local = TierPlan("kernel",
                             f"pallas segment_fold[{low.semiring}] "
                             f"block_n={block_n}{masked}", out_bytes,
                             predicted_us=costs["kernel"],
                             candidate_us=candidate_us)
        elif kind == "segment":
            op = _SEGMENT_OPS.get(m.name)
            if op is None:
                raise ValueError(
                    f"layout='segment' was requested but monoid {m.name!r} "
                    "has no XLA segment primitive (jax.ops.segment_*); use "
                    "layout='scan', or leave layout='auto' to let the cost "
                    "model pick among feasible tiers.")
            local = TierPlan("segment_ops", f"jax.ops.{op.__name__}{masked}",
                             out_bytes, predicted_us=costs["segment"],
                             candidate_us=candidate_us)
        else:
            local = TierPlan("scan",
                             f"serial scan (any monoid, Alg 4){masked}",
                             out_bytes, predicted_us=costs["scan"],
                             candidate_us=candidate_us)
    else:
        if layout in ("kernel", "segment"):
            raise ValueError(
                f"layout={layout!r} lowers a KEYED fold but no segment_ids= "
                "were given: pass segment_ids= (one key per record) and "
                "num_segments=, or use layout='tree'/'scan' for a flat fold")
        # with map_fn the point is O(1) live values — materializing for the
        # tree tier would defeat it, so auto considers the fused scan only
        candidates = ["scan"] if map_fn is not None else ["tree", "scan"]
        # 'async' fuses an in-mapper scan with pipelined crossings: its
        # local work is the scan tier's, chosen here; the whole-plan shape
        # is decided after the sync collectives are priced below
        eff_layout = "auto" if layout == "async" else layout
        shown = candidates + ([eff_layout]
                              if eff_layout not in ("auto", *candidates)
                              else [])
        candidate_us = tuple((c, local_us(c)) for c in shown)
        costs = dict(candidate_us)
        kind = (min(candidates, key=costs.get) if eff_layout == "auto"
                else eff_layout)
        if kind == "tree":
            local = TierPlan("tree",
                             f"log-depth tree fold (Alg 3 combiner){masked}",
                             out_bytes, predicted_us=costs["tree"],
                             candidate_us=candidate_us)
        else:
            local = TierPlan("scan",
                             f"in-mapper scan (Alg 4, O(1) live){masked}",
                             out_bytes, predicted_us=costs["scan"],
                             candidate_us=candidate_us)

    # -- collective tiers: ICI first, then DCN ------------------------------
    sizes = dict(axis_sizes or {})
    if mesh is not None:
        for ax, sz in mesh.shape.items():
            sizes.setdefault(ax, sz)
    algo = collective_algorithm(m)
    tiers = []
    if not pre_combine:
        # Algorithm 1: every VALID lifted pair crosses the wire un-combined.
        pair_bytes = n_model * vbytes
        wire = sum(collective_wire_bytes(pair_bytes, sizes.get(ax, 1),
                                         "gather") for ax in (mesh_axes or ()))
        pred = sum(
            calib.predict_link_us(_link_domain(ax),
                                  float(pair_bytes) * (sizes[ax] - 1))
            for ax in (mesh_axes or ())
            if sizes.get(ax) and sizes[ax] > 1)
        tiers.append(TierPlan("gather_pairs",
                              "no combiner: all pairs shuffled (Alg 1)",
                              pair_bytes, wire, predicted_us=float(pred)))
        tiers.append(local)
    else:
        tiers.append(local)
        if mesh_axes:
            ici, dcn = _split_ici_dcn(mesh_axes)
            comp_bytes = spec.wire_bytes(value_shape) if spec else 0
            for ax in ici:
                tiers.append(_plan_collective_tier(
                    calib, "ici", ax, sizes.get(ax), out_bytes,
                    num_segments if keyed else None, algo))
            for ax in dcn:
                if spec is not None and not keyed:
                    tiers.append(_plan_lossy_dcn_tier(
                        calib, ax, sizes.get(ax), comp_bytes, out_bytes,
                        spec))
                else:
                    tiers.append(_plan_collective_tier(
                        calib, "dcn", ax, sizes.get(ax), out_bytes,
                        num_segments if keyed else None, algo))

    # -- overlap / compression annotations + the sync-vs-async argmin --------
    overlap_modeled = 0.0
    dense_wire = lossy_wire = 0
    plan_cand: Tuple[Tuple[str, float], ...] = ()
    if not keyed and mesh_axes and pre_combine:
        ici, dcn = _split_ici_dcn(mesh_axes)
        comp_bytes = spec.wire_bytes(value_shape) if spec else 0
        for ax in dcn:
            P = sizes.get(ax)
            if P and P > 1:
                dense_wire += int(_per_device_shuffle_bytes(
                    out_bytes, P, "allreduce", algo))
                lossy_wire += (comp_bytes * (P - 1) if spec is not None
                               else int(_per_device_shuffle_bytes(
                                   out_bytes, P, "allreduce", algo)))
        if n > 1 and layout in ("auto", "async"):
            async_tier, async_total, modeled = _plan_async_tier(
                calib, n=n, value_bytes=vbytes, out_bytes=out_bytes,
                local_us_total=local.predicted_us, ici=ici, dcn=dcn,
                sizes=sizes, spec=spec, comp_bytes=comp_bytes, algo=algo)
            sync_total = float(sum(t.predicted_us for t in tiers))
            plan_cand = (("sync", sync_total), ("async", async_total))
            # a predicted tie goes to sync: one crossing beats n crossings
            # whenever the model cannot prove the extra n-1 are hidden
            if layout == "async" or async_total < sync_total:
                async_tier = dataclasses.replace(async_tier,
                                                 candidate_us=plan_cand)
                tiers = [async_tier]
                overlap_modeled = modeled
                lossy_wire = (comp_bytes * sum(
                    sizes[ax] - 1 for ax in dcn
                    if sizes.get(ax) and sizes[ax] > 1) * n
                    if spec is not None else dense_wire * n)
                dense_wire *= n
    return Plan(monoid=m, tiers=tuple(tiers), num_records=n,
                num_segments=num_segments, value_bytes=vbytes,
                out_bytes=out_bytes, num_valid=num_valid,
                lossy=spec.describe() if spec else None,
                overlap_modeled=overlap_modeled,
                dense_wire_bytes=dense_wire, lossy_wire_bytes=lossy_wire,
                plan_candidate_us=plan_cand)


# ---------------------------------------------------------------------------
# tier implementations
# ---------------------------------------------------------------------------

def _seg_add_init(m: Monoid, folded: Pytree, init: Optional[Pytree]) -> Pytree:
    if init is None:
        return folded
    return jax.vmap(m.combine)(init, folded)


def _segment_fold_generic(m: Monoid, values: Pytree, segment_ids: jnp.ndarray,
                          num_segments: int, init: Optional[Pytree] = None, *,
                          lifted: bool = True,
                          map_fn: Optional[Callable] = None,
                          valid_mask: Optional[jnp.ndarray] = None) -> Pytree:
    """O(N) serial scan — works for ANY monoid (the associative array of
    Alg 4).  With ``lifted=False``/``map_fn`` the lift runs inside the scan
    step, so per-record values are never materialized (true in-mapper
    combining).  Rows where ``valid_mask`` is False contribute the monoid
    identity — combine with it is a no-op, so the ragged fold equals the
    fold over only the valid rows for ANY monoid."""
    def prep(x):
        if map_fn is not None:
            return m.lift(map_fn(x))
        return x if lifted else m.lift(x)

    first = jax.tree_util.tree_map(lambda v: v[0], values)
    one = m.identity_like(prep(first))
    if init is None:
        init = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (num_segments,) + l.shape), one)

    mask = (None if valid_mask is None
            else jnp.asarray(valid_mask, jnp.bool_))

    def step(acc, kv):
        if mask is None:
            k, x = kv
            v = prep(x)
        else:
            k, valid, x = kv
            v = prep(x)
            v = jax.tree_util.tree_map(
                lambda l, i: jnp.where(valid, l,
                                       jnp.asarray(i, jnp.asarray(l).dtype)),
                v, one)
        cur = jax.tree_util.tree_map(lambda a: a[k], acc)
        new = m.combine(cur, v)
        acc = jax.tree_util.tree_map(lambda a, n_: a.at[k].set(n_), acc, new)
        return acc, None

    xs = (segment_ids, values) if mask is None else (segment_ids, mask, values)
    acc, _ = jax.lax.scan(step, init, xs)
    return acc


def _materialize_lifted(m: Monoid, values: Pytree, lifted: bool,
                        map_fn: Optional[Callable]) -> Pytree:
    if map_fn is not None:
        return jax.vmap(lambda x: m.lift(map_fn(x)))(values)
    if not lifted:
        return jax.vmap(m.lift)(values)
    return values


def _scan_fold_map(m: Monoid, values: Pytree, map_fn: Callable,
                   axis: int,
                   valid_mask: Optional[jnp.ndarray] = None) -> Pytree:
    """Flat in-mapper fold: lift(map_fn(x)) folded in a lax.scan carry.
    Invalid rows fold the identity (a combine no-op)."""
    def move(x):
        return jnp.moveaxis(x, axis, 0) if axis != 0 else x

    values = jax.tree_util.tree_map(move, values)
    one = _one_slice(values)
    out_shape = jax.eval_shape(lambda x: m.lift(map_fn(x)), one)
    init = m.identity_like(out_shape)

    if valid_mask is None:
        def step(acc, x):
            return m.combine(acc, m.lift(map_fn(x))), None

        acc, _ = jax.lax.scan(step, init, values)
        return acc

    def step_masked(acc, vx):
        valid, x = vx
        v = m.lift(map_fn(x))
        v = jax.tree_util.tree_map(
            lambda l, i: jnp.where(valid, l,
                                   jnp.asarray(i, jnp.asarray(l).dtype)),
            v, init)
        return m.combine(acc, v), None

    acc, _ = jax.lax.scan(step_masked, init,
                          (jnp.asarray(valid_mask, jnp.bool_), values))
    return acc


# ---------------------------------------------------------------------------
# the single entry point
# ---------------------------------------------------------------------------

def execute_fold(m: Monoid, values: Pytree, *, segment_ids=None,
                 num_segments: Optional[int] = None,
                 valid_mask=None,
                 mesh_axes: Optional[Sequence[Any]] = None,
                 layout: str = "auto", lifted: bool = True,
                 map_fn: Optional[Callable] = None,
                 init: Optional[Pytree] = None, axis: int = 0,
                 block_n: int = 512, interpret: Optional[bool] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 axis_sizes: Optional[Mapping[Any, int]] = None,
                 lossy=None, ef: Optional[Pytree] = None,
                 calibration: Optional[Calibration] = None,
                 with_plan: bool = False) -> Pytree:
    """Fold monoid values through the planner-chosen tiers.

    values: pytree with leading (or ``axis``) batch dim.  With
    ``segment_ids`` (and ``num_segments``) the fold is keyed — a MapReduce
    'reduce by key' returning a (num_segments, ...) table.  With
    ``mesh_axes`` the local result is additionally combined across the named
    mesh axes (must run inside shard_map), fast ICI axes before the slow DCN
    ``pod`` axis.

    ``valid_mask`` (one bool per record) makes the fold ragged: invalid rows
    contribute the monoid identity on every tier — the kernel and
    segment-ops tiers route them to the out-of-range segment id (dropped by
    the one-hot / the XLA scatter), the generic tiers fold the identity.
    The result equals the fold over only the valid rows.

    layout: 'auto' argmins the calibrated cost model over the feasible
    tiers (see :func:`plan_fold`); 'kernel' / 'segment' / 'scan' / 'tree'
    force a tier.  The plan also carries the shuffle-algorithm choice per
    collective axis, and the keyed mesh combine executes exactly what the
    plan says (reduce-scatter + all-gather or allreduce).  ``map_fn`` maps
    raw inputs (then ``m.lift``) without materializing them on scan tiers —
    the in-mapper combining of Algorithm 4.  ``lifted=False`` applies
    ``m.lift`` to each element first.

    ``lossy=`` (flat additive folds with ``mesh_axes=``) crosses the DCN
    axis compressed, with error feedback: the return value becomes the pair
    ``(folded, new_ef)`` where ``new_ef`` is the residual fold state to pass
    back as ``ef=`` on the next step (``None`` starts from zeros).
    ``layout='async'`` executes the double-buffered microbatch fold of
    :func:`repro.dist.collectives.async_microbatch_fold`; the surrounding
    ``shard_map`` needs ``check_rep=False`` (the scan carry's replication
    defeats the static checker).

    Returns the folded value — or ``(value, plan)`` with ``with_plan=True``.
    """
    plan_mask = valid_mask
    if valid_mask is not None and not isinstance(valid_mask,
                                                 jax.ShapeDtypeStruct):
        # plan from the mask's SHAPE only: counting a concrete device mask
        # would block dispatch just for byte bookkeeping, and tier choice
        # falls back to the conservative all-rows count.  Call plan_fold
        # directly for the counted byte model.
        plan_mask = jax.ShapeDtypeStruct(jnp.shape(valid_mask), jnp.bool_)
    plan = plan_fold(m, values, segment_ids=segment_ids,
                     num_segments=num_segments, valid_mask=plan_mask,
                     mesh_axes=mesh_axes,
                     layout=layout, lifted=lifted, map_fn=map_fn, mesh=mesh,
                     axis_sizes=axis_sizes, block_n=block_n, lossy=lossy,
                     calibration=calibration)
    kind = plan.local_tier.kind
    keyed = segment_ids is not None
    if valid_mask is not None and axis != 0:
        raise ValueError("valid_mask requires the batch axis at 0")

    spec = None
    if lossy is not None:
        from ..optim.compress import LossySpec
        spec = LossySpec.parse(lossy)

    if kind == "async":
        if valid_mask is not None:
            raise ValueError("layout='async' does not support valid_mask; "
                             "mask rows to the identity before the fold")
        if axis != 0:
            raise ValueError("async folds require the batch axis at 0")
        if init is not None:
            raise ValueError("init is only supported for keyed folds")
        from ..dist.collectives import async_microbatch_fold
        if spec is not None and ef is None:
            one = _lifted_value_shape(m, values, lifted, map_fn)
            ef = jax.tree_util.tree_map(
                lambda l: jnp.zeros(l.shape, jnp.float32), one)
        out, new_ef = async_microbatch_fold(m, values, mesh_axes,
                                            map_fn=map_fn, lifted=lifted,
                                            lossy=spec, ef=ef)
        result = (out, new_ef) if spec is not None else out
        return (result, plan) if with_plan else result

    if keyed:
        if axis != 0:
            raise ValueError("keyed folds require the batch axis at 0")
        if kind == "kernel":
            mat = _materialize_lifted(m, values, lifted, map_fn)
            folded = m.kernel_lowering().fn(mat, segment_ids, num_segments,
                                            block_n=block_n,
                                            valid_mask=valid_mask,
                                            interpret=interpret)
            out = _seg_add_init(m, folded, init)
        elif kind == "segment_ops":
            mat = _materialize_lifted(m, values, lifted, map_fn)
            seg = _mask_segment_ids(segment_ids, valid_mask, num_segments)
            op = _SEGMENT_OPS[m.name]
            folded = jax.tree_util.tree_map(
                lambda v: op(v, seg, num_segments=num_segments), mat)
            out = _seg_add_init(m, folded, init)
        else:
            out = _segment_fold_generic(m, values, segment_ids, num_segments,
                                        init, lifted=lifted, map_fn=map_fn,
                                        valid_mask=valid_mask)
    else:
        if init is not None:
            raise ValueError("init is only supported for keyed folds")
        if kind == "tree":
            mat = _materialize_lifted(m, values, lifted, map_fn)
            if valid_mask is not None:
                mat = _mask_rows_to_identity(m, mat, valid_mask)
            out = tree_fold(m, mat, axis=axis)
        elif map_fn is not None:
            out = _scan_fold_map(m, values, map_fn, axis,
                                 valid_mask=valid_mask)
        else:
            mat = _materialize_lifted(m, values, lifted, map_fn)
            if valid_mask is not None:
                mat = _mask_rows_to_identity(m, mat, valid_mask)
            out = scan_fold(m, mat, axis=axis)

    if mesh_axes:
        from ..dist.collectives import (combine_keyed_table,
                                        cross_axes_allreduce,
                                        lossy_cross_axes,
                                        split_axis_names)
        if spec is not None:
            if ef is None:
                ef = jax.tree_util.tree_map(
                    lambda l: jnp.zeros(jnp.shape(l), jnp.float32), out)
            out, ef = lossy_cross_axes(spec, out, mesh_axes, ef=ef)
        else:
            coll = [t for t in plan.tiers
                    if t.kind in ("allreduce", "reduce_scatter")]
            if keyed and any(t.kind == "reduce_scatter" for t in coll):
                # execute the plan's per-axis shuffle choice: axis order here
                # (ICI then DCN) matches the planner's tier order by
                # construction
                ici, dcn = split_axis_names(mesh_axes)
                for ax, tier in zip(tuple(ici) + tuple(dcn), coll):
                    out = combine_keyed_table(m, out, ax, algorithm=tier.kind)
            else:
                out = cross_axes_allreduce(m, out, mesh_axes)
    if spec is not None:
        if ef is None:   # lossy annotation but no mesh: residual stays zero
            ef = jax.tree_util.tree_map(
                lambda l: jnp.zeros(jnp.shape(l), jnp.float32), out)
        result = (out, ef)
        return (result, plan) if with_plan else result
    return (out, plan) if with_plan else out


# ---------------------------------------------------------------------------
# keyed-fold compatibility wrapper (the pre-planner public API)
# ---------------------------------------------------------------------------

def segment_fold(m: Monoid, values: Pytree, segment_ids: jnp.ndarray,
                 num_segments: int, *, init: Optional[Pytree] = None,
                 impl: str = "auto") -> Pytree:
    """Key-grouped monoid fold: MapReduce 'reduce by key', shapes static.

    Thin wrapper over :func:`execute_fold` kept for callers that predate the
    planner.  impl: 'auto' — segment primitive when the monoid admits one,
    else the generic scan; 'onehot' — the one-hot matmul strategy (additive
    monoids only): the Pallas kernel tier when it applies (TPU backend,
    kernel-compatible dtypes), the historical pure-XLA ``jax.nn.one_hot``
    matmul otherwise; either way results are cast back to each input leaf's
    dtype, the pre-planner onehot contract; 'scan' — force the generic path.
    """
    if impl == "onehot":
        if m.name not in ("sum", "mean", "count"):
            raise ValueError("onehot impl is only meaningful for additive monoids")
        if (jax.default_backend() == "tpu"
                and _kernel_compatible(m, _one_slice(values))):
            out = execute_fold(m, values, segment_ids=segment_ids,
                               num_segments=num_segments, init=init,
                               layout="kernel")
            return jax.tree_util.tree_map(
                lambda o, v: o.astype(jnp.asarray(v).dtype), out, values)
        # Pure-XLA one-hot matmul, the pre-planner implementation: off TPU
        # the Pallas kernel only runs in interpret mode, and it also rejects
        # leaves (e.g. bool) the matmul's f32 cast handles fine.  Explicit
        # layout='kernel' through execute_fold stays the always-Pallas path.
        def onehot_sum(v):
            v2 = jnp.asarray(v)
            flat = v2.reshape((v2.shape[0], -1)).astype(jnp.float32)
            oh = jax.nn.one_hot(segment_ids, num_segments,
                                dtype=jnp.float32, axis=0)
            out = oh @ flat  # (S, V) on the MXU
            return out.reshape((num_segments,) + v2.shape[1:]).astype(v2.dtype)
        folded = jax.tree_util.tree_map(onehot_sum, values)
        return _seg_add_init(m, folded, init)
    elif impl == "scan":
        layout = "scan"
    elif impl == "auto":
        layout = "segment" if m.name in _SEGMENT_OPS else "scan"
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return execute_fold(m, values, segment_ids=segment_ids,
                        num_segments=num_segments, init=init, layout=layout)
