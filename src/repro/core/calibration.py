"""The calibrated time/byte cost model behind the execution planner.

``plan_fold`` (core/plan.py) used to pick tiers by backend detection and
hand-rolled byte formulas.  This module closes the ROADMAP's "measure,
don't guess" loop: every placement decision — kernel vs segment-ops vs
scan, reduce-scatter vs allreduce — becomes an argmin over *predicted
microseconds*, and the coefficients behind the prediction come from
on-device microbenchmarks (``benchmarks/roofline.py --calibrate``), the
external-memory MapReduce cost model of Greiner & Jacob made concrete.

The model, per local tier (kernel / segment_ops / scan / tree):

    t(n, b) = t0_us + n * us_per_record + n * b * us_per_byte

where ``n`` is the record count and ``b`` the bytes of one lifted monoid
value — a launch-overhead term, a serial per-record term (dominant for the
scan tier), and a throughput term.  Per collective link (ici / dcn):

    t(bytes) = launches * t0_us + per_device_wire_bytes * us_per_byte

Coefficients are keyed ``"{monoid}|{dtype}"`` with a fallback chain down
to the tier-wide ``"*"`` entry, so a calibration may be as coarse (one
number per tier) or as fine (per-(backend, dtype, monoid)) as was
measured.

Tables are cached on disk as versioned JSON — ``$REPRO_CALIB`` if set
(the values ``none``/``off``/``default`` disable the disk cache entirely,
which is how the test suite pins the shipped default), else
``~/.cache/repro/calib.json``.  A table whose ``version`` does not match
:data:`CALIB_VERSION` is stale and silently ignored in favor of the
shipped default, so a schema change can never mis-drive the planner.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
from typing import Dict, Mapping, Optional, Tuple

CALIB_VERSION = 2

# tier kinds the model knows; 'segment' (the layout spelling) maps to
# 'segment_ops' (the TierPlan.kind spelling) in plan.py
TIER_KINDS = ("kernel", "segment_ops", "scan", "tree")
LINK_DOMAINS = ("ici", "dcn")

_ENV_VAR = "REPRO_CALIB"
_DISABLED = ("none", "off", "default", "")


@dataclasses.dataclass(frozen=True)
class TierCoeff:
    """Coefficients of one tier's (or link's) time model, in microseconds.

    t0_us: fixed launch/dispatch overhead.
    us_per_byte: inverse throughput (for links: inverse wire bandwidth).
    us_per_record: serial per-record cost (0 for links; dominant for the
      scan tier, whose lax.scan executes one combine per record).
    """

    t0_us: float
    us_per_byte: float
    us_per_record: float = 0.0
    # links only: fraction of this link's in-flight time that a
    # double-buffered schedule can hide under independent compute.  0 means
    # crossings fully serialize with compute (the CPU fake-device runtime);
    # ~1 means the DMA engines run free (TPU DCN).  Measured by
    # ``roofline.py --calibrate`` from a dbuf-vs-serial probe.
    overlap_frac: float = 0.0

    def local_us(self, num_records: int, record_bytes: int) -> float:
        return (self.t0_us + num_records * self.us_per_record
                + num_records * record_bytes * self.us_per_byte)

    def link_us(self, wire_bytes: float, launches: int = 1) -> float:
        return launches * self.t0_us + wire_bytes * self.us_per_byte


def _coeff_to_json(c: TierCoeff) -> Dict[str, float]:
    return {"t0_us": c.t0_us, "us_per_byte": c.us_per_byte,
            "us_per_record": c.us_per_record,
            "overlap_frac": c.overlap_frac}


def _coeff_from_json(d: Mapping[str, float]) -> TierCoeff:
    return TierCoeff(t0_us=float(d.get("t0_us", 0.0)),
                     us_per_byte=float(d.get("us_per_byte", 0.0)),
                     us_per_record=float(d.get("us_per_record", 0.0)),
                     overlap_frac=float(d.get("overlap_frac", 0.0)))


@dataclasses.dataclass(frozen=True)
class Calibration:
    """A versioned table of measured (or default) cost-model coefficients.

    tiers: tier kind -> {"monoid|dtype" | "monoid|*" | "*": TierCoeff}.
    collectives: "ici" / "dcn" -> TierCoeff (us_per_record unused).
    source: 'default' for the shipped table, 'measured' for a table written
      by ``benchmarks/roofline.py --calibrate``.
    """

    version: int
    backend: str
    source: str
    tiers: Mapping[str, Mapping[str, TierCoeff]]
    collectives: Mapping[str, TierCoeff]

    # -- lookup (specific -> generic fallback chain) -------------------------
    def tier_coeff(self, kind: str, monoid: str = "*",
                   dtype: str = "*") -> TierCoeff:
        table = self.tiers.get(kind, {})
        for key in (f"{monoid}|{dtype}", f"{monoid}|*", f"*|{dtype}", "*"):
            if key in table:
                return table[key]
        return TierCoeff(0.0, 0.0, 0.0)

    def link_coeff(self, domain: str) -> TierCoeff:
        if domain in self.collectives:
            return self.collectives[domain]
        return _DEFAULT_COLLECTIVES.get(domain, TierCoeff(0.0, 0.0))

    # -- prediction ----------------------------------------------------------
    def predict_local_us(self, kind: str, *, monoid: str, dtype: str,
                         num_records: int, record_bytes: int) -> float:
        return self.tier_coeff(kind, monoid, dtype).local_us(
            num_records, record_bytes)

    def predict_link_us(self, domain: str, wire_bytes: float,
                        launches: int = 1) -> float:
        return self.link_coeff(domain).link_us(wire_bytes, launches)

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> Dict:
        return {
            "version": self.version,
            "backend": self.backend,
            "source": self.source,
            "tiers": {k: {key: _coeff_to_json(c) for key, c in t.items()}
                      for k, t in self.tiers.items()},
            "collectives": {d: _coeff_to_json(c)
                            for d, c in self.collectives.items()},
        }

    @classmethod
    def from_json(cls, payload: Mapping) -> "Calibration":
        return cls(
            version=int(payload["version"]),
            backend=str(payload.get("backend", "unknown")),
            source=str(payload.get("source", "measured")),
            tiers={k: {key: _coeff_from_json(c) for key, c in t.items()}
                   for k, t in payload.get("tiers", {}).items()},
            collectives={d: _coeff_from_json(c)
                         for d, c in payload.get("collectives", {}).items()},
        )


# ---------------------------------------------------------------------------
# the shipped default table
# ---------------------------------------------------------------------------
# Coefficients chosen so the UNCALIBRATED planner reproduces the historical
# heuristic ordering on every backend: the kernel tier dominates whenever the
# feasibility filter admits it, segment-ops beats the serial scan, and the
# log-depth tree beats the scan for flat folds.  A measured table
# (`roofline.py --calibrate`) replaces these with real throughputs.

_DEFAULT_TIERS: Dict[str, Dict[str, TierCoeff]] = {
    "kernel":      {"*": TierCoeff(t0_us=1.5, us_per_byte=1e-5,
                                   us_per_record=4e-4)},
    "segment_ops": {"*": TierCoeff(t0_us=2.0, us_per_byte=5e-5,
                                   us_per_record=2e-3)},
    "scan":        {"*": TierCoeff(t0_us=2.0, us_per_byte=1e-4,
                                   us_per_record=1.5)},
    "tree":        {"*": TierCoeff(t0_us=2.0, us_per_byte=5e-5,
                                   us_per_record=2e-2)},
}

# ICI ~ tens of GB/s with ~10us launch; DCN ~ sub-GB/s with ~100us latency.
# overlap_frac is the TPU-flavored prior: DCN traffic rides DMA engines and
# mostly hides under compute; ICI hops are short enough that little is left
# to hide.  A measured table replaces both (CPU fake devices measure ~0).
_DEFAULT_COLLECTIVES: Dict[str, TierCoeff] = {
    "ici": TierCoeff(t0_us=10.0, us_per_byte=1e-4, overlap_frac=0.25),
    "dcn": TierCoeff(t0_us=100.0, us_per_byte=2e-3, overlap_frac=0.75),
}

_DEFAULT = Calibration(version=CALIB_VERSION, backend="any", source="default",
                       tiers=_DEFAULT_TIERS, collectives=_DEFAULT_COLLECTIVES)


def default_calibration() -> Calibration:
    """The shipped fallback table (used when no valid cache exists)."""
    return _DEFAULT


# ---------------------------------------------------------------------------
# on-disk cache
# ---------------------------------------------------------------------------

def calibration_path() -> Optional[str]:
    """Resolve the calibration cache path.

    ``$REPRO_CALIB`` wins when set; the sentinel values 'none'/'off'/
    'default' (or empty) return None — disk disabled, shipped default only.
    """
    env = os.environ.get(_ENV_VAR)
    if env is not None:
        if env.strip().lower() in _DISABLED:
            return None
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "calib.json")


def load_calibration(path: Optional[str] = None) -> Optional[Calibration]:
    """Load a calibration table; None when missing, unreadable, or stale.

    Staleness = ``version != CALIB_VERSION``: a table written under an old
    schema is treated exactly like no table at all (invalidation by
    version, never by reinterpretation).
    """
    path = path if path is not None else calibration_path()
    if path is None:
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(payload, dict):
        return None
    if payload.get("version") != CALIB_VERSION:
        return None
    try:
        return Calibration.from_json(payload)
    except (KeyError, TypeError, ValueError):
        return None


def save_calibration(calib: Calibration, path: Optional[str] = None) -> str:
    """Write ``calib`` to ``path`` (default: the resolved cache path)."""
    path = path if path is not None else calibration_path()
    if path is None:
        raise ValueError(
            f"calibration cache is disabled (${_ENV_VAR}={os.environ.get(_ENV_VAR)!r}); "
            "pass an explicit path")
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(calib.to_json(), f, indent=1, sort_keys=True)
    return path


# ---------------------------------------------------------------------------
# the active calibration (what plan_fold consults)
# ---------------------------------------------------------------------------

_lock = threading.Lock()
_active: Optional[Calibration] = None           # explicit override
_cache: Tuple[Optional[Tuple[str, float]], Optional[Calibration]] = (None, None)


def set_calibration(calib: Optional[Calibration]) -> None:
    """Install ``calib`` as the active table (None restores env/disk/default
    resolution)."""
    global _active
    with _lock:
        _active = calib


@contextlib.contextmanager
def use_calibration(calib: Calibration):
    """Scoped override — how tests inject synthetic tables."""
    global _active
    with _lock:
        prev, _active = _active, calib
    try:
        yield calib
    finally:
        with _lock:
            _active = prev


def get_calibration() -> Calibration:
    """The table plan_fold predicts from: explicit override > valid disk
    cache (memoized by path + mtime) > shipped default."""
    global _cache
    with _lock:
        if _active is not None:
            return _active
    path = calibration_path()
    if path is None:
        return _DEFAULT
    try:
        key = (path, os.path.getmtime(path))
    except OSError:
        return _DEFAULT
    with _lock:
        if _cache[0] == key and _cache[1] is not None:
            return _cache[1]
    loaded = load_calibration(path) or _DEFAULT
    with _lock:
        _cache = (key, loaded)
    return loaded


# ---------------------------------------------------------------------------
# the double-buffered pipeline model (the async execution tier)
# ---------------------------------------------------------------------------

def pipeline_exposed_us(*, num_crossings: int, slot_us: float,
                        cross_us: float) -> Tuple[float, float]:
    """Exposed microseconds of ``num_crossings`` link crossings in a
    double-buffered microbatch pipeline, BEFORE applying the link's
    measured ``overlap_frac``.

    Crossing *i* is in flight while microbatch slot *i+1* computes, so each
    of the first ``n - 1`` crossings can hide up to one compute slot; the
    epilogue crossing has nothing left to hide under and is always exposed.
    Returns ``(exposed_us, hideable_us)`` with
    ``exposed + hideable == num_crossings * cross_us``.
    """
    n = max(int(num_crossings), 0)
    total = n * max(cross_us, 0.0)
    if n <= 1 or total <= 0.0:
        return total, 0.0
    hideable = (n - 1) * min(max(slot_us, 0.0), cross_us)
    return total - hideable, hideable


def predict_overlap(calib: "Calibration", domain: str, *,
                    num_crossings: int, slot_us: float,
                    wire_bytes: float) -> Tuple[float, float]:
    """Predicted ``(exposed_us, overlap_fraction)`` for ``num_crossings``
    double-buffered crossings of ``wire_bytes`` each over ``domain``.

    The link's calibrated ``overlap_frac`` scales the structurally hideable
    time: a runtime whose collectives serialize with compute (overlap_frac
    0) exposes the full ``n * cross_us`` no matter the schedule.
    """
    coeff = calib.link_coeff(domain)
    cross_us = coeff.link_us(wire_bytes)
    exposed, hideable = pipeline_exposed_us(
        num_crossings=num_crossings, slot_us=slot_us, cross_us=cross_us)
    hidden = hideable * min(max(coeff.overlap_frac, 0.0), 1.0)
    total = num_crossings * cross_us
    if total <= 0.0:
        return 0.0, 0.0
    return total - hidden, hidden / total


# ---------------------------------------------------------------------------
# coefficient fitting (used by benchmarks/roofline.py --calibrate)
# ---------------------------------------------------------------------------

def fit_tier_coeff(*, n1: int, b1: int, t11_us: float,
                   n2: int, t21_us: float,
                   b2: int, t22_us: float) -> TierCoeff:
    """Fit ``t(n, b) = t0 + n*us_per_record + n*b*us_per_byte`` from three
    measurements: (n1, b1), (n2, b1), (n2, b2) — vary the record count at
    fixed record bytes, then the record bytes at fixed count.  Negative
    intermediate slopes (timing noise) clamp to 0 so a fitted table can
    never predict negative time.
    """
    if n2 <= n1 or b2 <= b1:
        raise ValueError(f"need n2 > n1 and b2 > b1; got n=({n1},{n2}) "
                         f"b=({b1},{b2})")
    us_per_byte = max((t22_us - t21_us) / (n2 * (b2 - b1)), 0.0)
    slope_n = max((t21_us - t11_us) / (n2 - n1), 0.0)
    us_per_record = max(slope_n - b1 * us_per_byte, 0.0)
    t0 = max(t11_us - n1 * slope_n, 0.0)
    return TierCoeff(t0_us=t0, us_per_byte=us_per_byte,
                     us_per_record=us_per_record)


def fit_link_coeff(*, bytes1: int, t1_us: float,
                   bytes2: int, t2_us: float,
                   overlap_frac: float = 0.0) -> TierCoeff:
    """Fit ``t(bytes) = t0 + bytes*us_per_byte`` from two payload sizes."""
    if bytes2 <= bytes1:
        raise ValueError(f"need bytes2 > bytes1; got ({bytes1}, {bytes2})")
    us_per_byte = max((t2_us - t1_us) / (bytes2 - bytes1), 0.0)
    t0 = max(t1_us - bytes1 * us_per_byte, 0.0)
    return TierCoeff(t0_us=t0, us_per_byte=us_per_byte,
                     overlap_frac=overlap_frac)


def fit_overlap_frac(*, t_serial_us: float, t_dbuf_us: float,
                     t_compute_us: float) -> float:
    """Measured overlap coefficient of a link from three timings of the
    same microbatch fold: crossings serialized after each compute slot
    (``t_serial``), crossings double-buffered against the next slot
    (``t_dbuf``), and no crossings at all (``t_compute``).

    The crossings cost ``t_serial - t_compute`` un-overlapped; the dbuf
    schedule recovered ``t_serial - t_dbuf`` of it.  Clamped to [0, 1] —
    scheduling overhead can make dbuf slower than serial (measured on CPU
    fake devices), which is exactly an overlap coefficient of 0.
    """
    crossings_us = t_serial_us - t_compute_us
    if crossings_us <= 0.0:
        return 0.0
    return min(max((t_serial_us - t_dbuf_us) / crossings_us, 0.0), 1.0)
