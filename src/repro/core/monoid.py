"""The Monoid abstraction — the paper's contribution as a composable JAX module.

A monoid is ``(M, combine, identity)`` with ``combine`` associative and
``identity`` its two-sided unit.  Following the paper we split an aggregation
into four pieces (§2, "Monoidify!"):

    lift     : X -> M        "monoidify" a raw mapper output      (r -> (r, 1))
    combine  : M x M -> M    the associative op / combiner body   ((s,c),(s',c')) -> (s+s', c+c')
    identity : -> M          the unit                             (0, 0)
    extract  : M -> R        one-time post-processing (fn. 3)     (s,c) -> s/c

Monoid values are arbitrary pytrees of jax arrays so they flow through jit,
scan, collectives and checkpoints unchanged.  ``combine`` must be
shape/structure preserving — this is exactly the MapReduce combiner contract
(same input and output key-value type) that Algorithm 2 in the paper violates;
we enforce it with :func:`check_structure`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

Pytree = Any


def _default_lift(x: Pytree) -> Pytree:
    return x


def _default_extract(m: Pytree) -> Pytree:
    return m


# ---------------------------------------------------------------------------
# kernel lowerings — how the execution planner (core/plan.py) finds a Pallas
# kernel for a monoid
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelLowering:
    """A registered accelerator lowering for a monoid's keyed fold.

    semiring: which semiring the kernel's one-hot matmul/reduce runs in
      ('sum' for additive monoids, 'max'/'min' for the max-plus family).
    fn: ``(values, seg_ids, num_segments, *, block_n, valid_mask,
      interpret) -> table`` — applied leaf-wise to the lifted value pytree;
      returns the per-key table with leading axis ``num_segments``.
      ``valid_mask`` (one bool per record, or None) marks rows that must
      contribute the semiring identity — ragged/padded batches.
    """

    semiring: str
    fn: Callable[..., Pytree]


# Keyed by Monoid.name. Monoids are frozen/static, so the registry is the
# mutable side-table that lets kernels/ register lowerings without core
# importing kernels at module load.
_KERNEL_LOWERINGS: Dict[str, KernelLowering] = {}


def register_kernel_lowering(name: str, lowering: KernelLowering) -> None:
    """Register (or replace) the accelerator lowering for monoid ``name``."""
    _KERNEL_LOWERINGS[name] = lowering


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Monoid:
    """An algebraic monoid over pytrees of jax arrays.

    Attributes:
      name: human-readable name (used in error messages / benchmarks).
      combine: associative binary op ``M x M -> M``.
      identity_fn: zero-arg callable returning the identity element. For
        shape-polymorphic monoids (e.g. Sum over arbitrary arrays) it may
        require an ``example`` kwarg — use :meth:`identity_like`.
      lift: ``X -> M`` ("monoidify" a raw value). Defaults to the id function.
      extract: ``M -> R`` one-time post-processing. Defaults to id.
      commutative: whether combine is commutative (True for everything in the
        zoo except explicitly-ordered monoids like ``concat``/First/Last).
        Hierarchical reductions that reorder operands check this flag.
      approx_equal: optional custom equality used by law checking (sketches
        compare exactly; float monoids use allclose).
    """

    name: str
    combine: Callable[[Pytree, Pytree], Pytree]
    identity_fn: Callable[..., Pytree]
    lift: Callable[[Pytree], Pytree] = _default_lift
    extract: Callable[[Pytree], Pytree] = _default_extract
    commutative: bool = True
    approx_equal: Optional[Callable[[Pytree, Pytree], bool]] = None

    # -- construction helpers -------------------------------------------------
    def identity(self) -> Pytree:
        return self.identity_fn()

    def identity_like(self, example: Pytree) -> Pytree:
        """Identity element with the shapes/dtypes of ``example``."""
        try:
            return self.identity_fn(example=example)
        except TypeError:
            return self.identity_fn()

    def kernel_lowering(self) -> Optional[KernelLowering]:
        """The registered Pallas lowering for this monoid, or None.

        The execution planner (:mod:`repro.core.plan`) consults this to decide
        whether the kernel tier is available for a keyed fold.
        """
        return _KERNEL_LOWERINGS.get(self.name)

    # -- algebra --------------------------------------------------------------
    def __call__(self, a: Pytree, b: Pytree) -> Pytree:
        return self.combine(a, b)

    def fold(self, xs: Pytree, *, axis: int = 0, lifted: bool = True) -> Pytree:
        """Fold a stacked batch of monoid values along ``axis``.

        ``xs`` is a pytree whose leaves each carry a leading (or ``axis``)
        batch dimension; returns the monoid combine of all slices. Uses a
        log-depth tree reduction (legal by associativity — the paper's whole
        point) rather than a serial loop.
        """
        if not lifted:
            xs = jax.vmap(self.lift, in_axes=axis, out_axes=axis)(xs)
        return tree_fold(self, xs, axis=axis)

    def equal(self, a: Pytree, b: Pytree, *, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
        if self.approx_equal is not None:
            return bool(self.approx_equal(a, b))
        la, sa = jax.tree_util.tree_flatten(a)
        lb, sb = jax.tree_util.tree_flatten(b)
        if sa != sb:
            return False
        for x, y in zip(la, lb):
            x = jnp.asarray(x)
            y = jnp.asarray(y)
            if x.shape != y.shape:
                return False
            if jnp.issubdtype(x.dtype, jnp.floating) or jnp.issubdtype(x.dtype, jnp.complexfloating):
                if not jnp.allclose(x, y, rtol=rtol, atol=atol):
                    return False
            else:
                if not jnp.array_equal(x, y):
                    return False
        return True


class MonoidTypeError(TypeError):
    """Raised when a combine would change the value's pytree structure/shape.

    This is the machine-checked version of the MapReduce combiner contract the
    paper's Algorithm 2 violates (combiner output type != input type).
    """


def check_structure(m: Monoid, a: Pytree, b: Pytree) -> None:
    """Verify ``combine(a, b)`` is structure & shape preserving."""
    out = m.combine(a, b)
    sa = jax.tree_util.tree_structure(a)
    so = jax.tree_util.tree_structure(out)
    if sa != so:
        raise MonoidTypeError(
            f"monoid {m.name!r}: combine changed pytree structure {sa} -> {so}; "
            "a MapReduce combiner must map M x M -> M (paper, Algorithm 2)"
        )
    for la, lo in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(out)):
        if jnp.shape(la) != jnp.shape(lo):
            raise MonoidTypeError(
                f"monoid {m.name!r}: combine changed leaf shape "
                f"{jnp.shape(la)} -> {jnp.shape(lo)}"
            )


def check_laws(m: Monoid, samples: list, *, rtol: float = 1e-4, atol: float = 1e-5) -> None:
    """Assert monoid laws on concrete samples (used by the hypothesis tests).

    Laws: associativity ``(a⊕b)⊕c == a⊕(b⊕c)``; left/right identity;
    structure preservation; commutativity if declared.
    """
    e = m.identity_like(samples[0]) if samples else m.identity()
    for a in samples:
        check_structure(m, a, a)
        assert m.equal(m.combine(e, a), a, rtol=rtol, atol=atol), f"{m.name}: left identity failed"
        assert m.equal(m.combine(a, e), a, rtol=rtol, atol=atol), f"{m.name}: right identity failed"
    for a in samples:
        for b in samples:
            if m.commutative:
                assert m.equal(m.combine(a, b), m.combine(b, a), rtol=rtol, atol=atol), (
                    f"{m.name}: commutativity failed"
                )
            for c in samples:
                lhs = m.combine(m.combine(a, b), c)
                rhs = m.combine(a, m.combine(b, c))
                assert m.equal(lhs, rhs, rtol=rtol, atol=atol), f"{m.name}: associativity failed"


# ---------------------------------------------------------------------------
# folds
# ---------------------------------------------------------------------------

def tree_fold(m: Monoid, xs: Pytree, *, axis: int = 0) -> Pytree:
    """Log-depth tree reduction of stacked monoid values along ``axis``.

    The batch size need not be a power of two: odd remainders are carried.
    Tracing cost is O(log n); this is the jit-friendly combiner. For very
    long folds with small state prefer :func:`scan_fold` (O(1) trace).
    """
    def move(x):
        return jnp.moveaxis(x, axis, 0) if axis != 0 else x

    xs = jax.tree_util.tree_map(move, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if n == 0:
        raise ValueError("tree_fold over empty batch; use identity_like instead")
    while n > 1:
        half = n // 2
        # pair ADJACENT elements so the re-bracketing preserves sequence
        # order — required for non-commutative monoids (affine_scan, concat)
        lo = jax.tree_util.tree_map(lambda x: x[0:2 * half:2], xs)
        hi = jax.tree_util.tree_map(lambda x: x[1:2 * half:2], xs)
        merged = jax.vmap(m.combine)(lo, hi)
        if n % 2:
            tail = jax.tree_util.tree_map(lambda x: x[-1:], xs)
            merged = jax.tree_util.tree_map(lambda a, b: jnp.concatenate([a, b], 0), merged, tail)
            n = half + 1
        else:
            n = half
        xs = merged
    return jax.tree_util.tree_map(lambda x: x[0], xs)


def scan_fold(m: Monoid, xs: Pytree, *, axis: int = 0, init: Optional[Pytree] = None) -> Pytree:
    """Serial in-mapper-combining fold: O(1) trace size, O(n) depth.

    This is the paper's Algorithm 4 — an accumulator held across inputs,
    emitted once at the end.  ``init`` defaults to the identity.
    """
    def move(x):
        return jnp.moveaxis(x, axis, 0) if axis != 0 else x

    xs = jax.tree_util.tree_map(move, xs)
    if init is None:
        first = jax.tree_util.tree_map(lambda x: x[0], xs)
        init = m.identity_like(first)

    def step(acc, x):
        return m.combine(acc, x), None

    acc, _ = jax.lax.scan(step, init, xs)
    return acc


def fold_map(m: Monoid, fn: Callable[[Pytree], Pytree], xs: Pytree, *, axis: int = 0,
             strategy: str = "scan") -> Pytree:
    """map-then-fold: ``fold(lift(fn(x)) for x in xs)`` without materializing.

    strategy='scan' is in-mapper combining (Algorithm 4: nothing materialized);
    strategy='tree' materializes the lifted values then tree-reduces
    (Algorithm 3: combiner on materialized map output).
    """
    if strategy == "scan":
        def move(x):
            return jnp.moveaxis(x, axis, 0) if axis != 0 else x
        xs = jax.tree_util.tree_map(move, xs)
        first = jax.tree_util.tree_map(lambda x: x[0], xs)
        init = m.identity_like(m.lift(fn(first)))

        def step(acc, x):
            return m.combine(acc, m.lift(fn(x))), None

        acc, _ = jax.lax.scan(step, init, xs)
        return acc
    elif strategy == "tree":
        lifted = jax.vmap(lambda x: m.lift(fn(x)), in_axes=axis, out_axes=0)(xs)
        return tree_fold(m, lifted, axis=0)
    raise ValueError(f"unknown strategy {strategy!r}")
