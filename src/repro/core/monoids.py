"""The monoid zoo.

Every aggregation in the framework is an instance from this module. The
paper's own examples are all here:

* ``mean`` — the running example (Algorithms 3/4): the ``(sum, count)`` pair.
* ``stripes`` / :func:`stripe_of_window` — Algorithm 5's associative arrays
  under element-wise sum (dense representation over a fixed vocab).
* ``bloom_filter`` / ``count_min`` / ``hyperloglog`` — the §3 Algebird
  sketches.
* weight vectors under addition (SGD, Lin & Kolcz) — that is just :func:`sum_`
  over a parameter pytree.

Beyond-paper monoids used by the LM stack:

* ``logsumexp`` and :func:`attn_state` — the online-softmax state; the reason
  chunked attention / flash-decoding / ring attention are legal re-bracketings.
* :func:`affine_scan` — linear-recurrence composition; why Mamba/mLSTM
  parallelize via ``lax.associative_scan``.
* ``welford`` — numerically-stable streaming mean/variance for metrics.

All monoid values are pytrees of jax arrays. Shape-polymorphic monoids
(sum/min/max/mean/...) take their shapes from ``identity_like(example)``.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .monoid import Monoid, Pytree

# ---------------------------------------------------------------------------
# elementwise pytree monoids (shape-polymorphic)
# ---------------------------------------------------------------------------

def _tree_binary(op):
    def combine(a, b):
        return jax.tree_util.tree_map(op, a, b)
    return combine


def _zeros_like_identity(*, example: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, example)


sum_ = Monoid(
    name="sum",
    combine=_tree_binary(jnp.add),
    identity_fn=_zeros_like_identity,
)
# Weight vectors under addition — the SGD monoid of Lin & Kolcz (paper §3) —
# and gradient accumulation are both `sum_` over a parameter pytree.
grad_sum = sum_

prod = Monoid(
    name="prod",
    combine=_tree_binary(jnp.multiply),
    identity_fn=lambda *, example: jax.tree_util.tree_map(jnp.ones_like, example),
)


def _neginf_like(x):
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.full_like(x, -jnp.inf)
    return jnp.full_like(x, jnp.iinfo(x.dtype).min)


def _posinf_like(x):
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.full_like(x, jnp.inf)
    return jnp.full_like(x, jnp.iinfo(x.dtype).max)


max_ = Monoid(
    name="max",
    combine=_tree_binary(jnp.maximum),
    identity_fn=lambda *, example: jax.tree_util.tree_map(_neginf_like, example),
)

min_ = Monoid(
    name="min",
    combine=_tree_binary(jnp.minimum),
    identity_fn=lambda *, example: jax.tree_util.tree_map(_posinf_like, example),
)

bitwise_or = Monoid(
    name="bitwise_or",
    combine=_tree_binary(jnp.bitwise_or),
    identity_fn=lambda *, example: jax.tree_util.tree_map(jnp.zeros_like, example),
)

# ---------------------------------------------------------------------------
# mean — the paper's running example (Algorithms 3/4)
# ---------------------------------------------------------------------------

def _mean_combine(a, b):
    (sa, ca), (sb, cb) = a, b
    return (jax.tree_util.tree_map(jnp.add, sa, sb), ca + cb)


def _mean_identity(*, example=None):
    if example is None:
        return (jnp.zeros(()), jnp.zeros((), jnp.int32))
    s, c = example
    return (jax.tree_util.tree_map(jnp.zeros_like, s), jnp.zeros_like(c))


mean = Monoid(
    name="mean",
    combine=_mean_combine,
    identity_fn=_mean_identity,
    lift=lambda r: (r, jnp.ones((), jnp.int32)),
    extract=lambda m: jax.tree_util.tree_map(
        lambda s: s / jnp.maximum(m[1], 1).astype(jnp.result_type(s, jnp.float32)), m[0]
    ),
)

count = Monoid(
    name="count",
    combine=jnp.add,
    identity_fn=lambda *, example=None: jnp.zeros((), jnp.int32),
    lift=lambda _: jnp.ones((), jnp.int32),
)

# ---------------------------------------------------------------------------
# Welford / Chan parallel variance — streaming (count, mean, M2)
# ---------------------------------------------------------------------------

def _welford_combine(a, b):
    na, ma, m2a = a
    nb, mb, m2b = b
    n = na + nb
    nf = jnp.maximum(n, 1.0)
    delta = mb - ma
    mean_ = ma + delta * (nb / nf)
    m2 = m2a + m2b + delta * delta * (na * nb / nf)
    return (n, mean_, m2)


welford = Monoid(
    name="welford",
    combine=_welford_combine,
    identity_fn=lambda *, example=None: (
        jnp.zeros(()) if example is None else jnp.zeros_like(example[0]),
        jnp.zeros(()) if example is None else jnp.zeros_like(example[1]),
        jnp.zeros(()) if example is None else jnp.zeros_like(example[2]),
    ),
    lift=lambda x: (jnp.ones_like(x), x, jnp.zeros_like(x)),
    extract=lambda m: {"count": m[0], "mean": m[1], "var": m[2] / jnp.maximum(m[0], 1.0)},
)

# ---------------------------------------------------------------------------
# logsumexp and the attention-state monoid (online softmax)
# ---------------------------------------------------------------------------

def _safe_coeff(m_old, m_new):
    """exp(m_old - m_new), with the convention exp(-inf - -inf) = 0."""
    return jnp.where(jnp.isneginf(m_old), 0.0, jnp.exp(m_old - m_new))


def _lse_combine(a, b):
    (ma, la), (mb, lb) = a, b
    m = jnp.maximum(ma, mb)
    return (m, la * _safe_coeff(ma, m) + lb * _safe_coeff(mb, m))


logsumexp = Monoid(
    name="logsumexp",
    combine=_lse_combine,
    identity_fn=lambda *, example=None: (
        (jnp.full((), -jnp.inf), jnp.zeros(())) if example is None
        else (jnp.full_like(example[0], -jnp.inf), jnp.zeros_like(example[1]))
    ),
    lift=lambda x: (x, jnp.ones_like(x)),
    extract=lambda m: m[0] + jnp.log(m[1]),
)


def _attn_combine(a, b):
    """Combine two partial softmax-attention states.

    State = (m, l, o): running max of logits, running sum of exp(logit - m),
    running sum of exp(logit - m) * V. Shapes: m, l: (...,); o: (..., d).
    This is the flash-attention / flash-decoding merge — associative, so any
    chunking/sharding of the KV axis is a legal re-bracketing (the paper's
    principle applied to softmax).
    """
    (ma, la, oa), (mb, lb, ob) = a, b
    m = jnp.maximum(ma, mb)
    ca = _safe_coeff(ma, m)
    cb = _safe_coeff(mb, m)
    l = la * ca + lb * cb
    o = oa * ca[..., None] + ob * cb[..., None]
    return (m, l, o)


def _attn_identity(*, example=None):
    if example is None:
        raise ValueError("attn_state identity requires an example (shape-polymorphic)")
    m, l, o = example
    return (jnp.full_like(m, -jnp.inf), jnp.zeros_like(l), jnp.zeros_like(o))


attn_state = Monoid(
    name="attn_state",
    combine=_attn_combine,
    identity_fn=_attn_identity,
    extract=lambda s: s[2] / jnp.maximum(s[1], 1e-30)[..., None],
)

# ---------------------------------------------------------------------------
# affine-map composition — linear recurrences (Mamba / mLSTM / prefix sums)
# ---------------------------------------------------------------------------

def _affine_combine(f, g):
    """Compose x -> g(f(x)) for affine maps f=(a1,b1), g=(a2,b2).

    (g∘f)(x) = a2*(a1*x + b1) + b2 = (a2*a1)*x + (a2*b1 + b2).
    Elementwise `a` covers diagonal state matrices (Mamba's Ā).
    NOT commutative — sequence order matters.
    """
    a1, b1 = f
    a2, b2 = g
    return (a2 * a1, a2 * b1 + b2)


affine_scan = Monoid(
    name="affine_scan",
    combine=_affine_combine,
    identity_fn=lambda *, example=None: (
        (jnp.ones(()), jnp.zeros(())) if example is None
        else (jnp.ones_like(example[0]), jnp.zeros_like(example[1]))
    ),
    commutative=False,
    extract=lambda f: f[1],  # applied to initial state 0: h = b
)

# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------

def top_k(k: int) -> Monoid:
    """Monoid of the k largest (value, id) pairs, values sorted descending."""

    def combine(a, b):
        va, ia = a
        vb, ib = b
        v = jnp.concatenate([va, vb], axis=-1)
        i = jnp.concatenate([ia, ib], axis=-1)
        vals, idx = jax.lax.top_k(v, k)
        return (vals, jnp.take_along_axis(i, idx, axis=-1))

    def identity_fn(*, example=None):
        if example is None:
            return (jnp.full((k,), -jnp.inf), jnp.full((k,), -1, jnp.int32))
        v, i = example
        return (jnp.full_like(v, -jnp.inf), jnp.full_like(i, -1))

    def lift(vi):
        v, i = vi
        pad_v = jnp.full((k - 1,), -jnp.inf, jnp.result_type(v, jnp.float32))
        pad_i = jnp.full((k - 1,), -1, jnp.int32)
        return (jnp.concatenate([jnp.atleast_1d(v).astype(pad_v.dtype), pad_v]),
                jnp.concatenate([jnp.atleast_1d(i).astype(jnp.int32), pad_i]))

    return Monoid(name=f"top{k}", combine=combine, identity_fn=identity_fn, lift=lift)

# ---------------------------------------------------------------------------
# hashing utilities for the sketch monoids
# ---------------------------------------------------------------------------

_HASH_PRIMES = np.array([
    0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1,
    0xD3A2646C, 0xFD7046C5, 0xB55A4F09, 0x8DA6B343, 0xD8163841,
], dtype=np.uint32)


def _uhash(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Multiply-xorshift universal hash of int token ids -> uint32."""
    x = x.astype(jnp.uint32)
    a = jnp.uint32(_HASH_PRIMES[seed % len(_HASH_PRIMES)])
    b = jnp.uint32(_HASH_PRIMES[(seed + 3) % len(_HASH_PRIMES)])
    h = (x ^ (x >> 16)) * a
    h = (h ^ (h >> 13)) * b
    return h ^ (h >> 16)

# ---------------------------------------------------------------------------
# Bloom filter (paper §3, [Bloom 1970])
# ---------------------------------------------------------------------------

def bloom_filter(num_bits: int, num_hashes: int = 4) -> Monoid:
    """Bloom filter over int ids. Monoid under bitwise OR; identity = empty."""
    assert num_bits % 8 == 0

    def lift(item):
        idx = jnp.stack([_uhash(item, s) % num_bits for s in range(num_hashes)])
        bits = jnp.zeros((num_bits,), jnp.uint8).at[idx].set(1)
        return bits

    m = Monoid(
        name=f"bloom({num_bits},{num_hashes})",
        combine=_tree_binary(jnp.bitwise_or),
        identity_fn=lambda *, example=None: jnp.zeros((num_bits,), jnp.uint8),
        lift=lift,
    )
    return m


def bloom_contains(filt: jnp.ndarray, item: jnp.ndarray, num_hashes: int = 4) -> jnp.ndarray:
    num_bits = filt.shape[-1]
    idx = jnp.stack([_uhash(item, s) % num_bits for s in range(num_hashes)])
    return jnp.all(filt[idx] > 0)

# ---------------------------------------------------------------------------
# count-min sketch (paper §3, [Cormode & Muthukrishnan 2005])
# ---------------------------------------------------------------------------

def count_min(depth: int, width: int) -> Monoid:
    """Count-min sketch: (depth, width) counters; monoid under elementwise +."""

    def lift(item):
        # one item -> a (depth, width) one-hot increment
        sk = jnp.zeros((depth, width), jnp.int32)
        for d in range(depth):
            sk = sk.at[d, _uhash(item, d) % width].add(1)
        return sk

    return Monoid(
        name=f"cms({depth},{width})",
        combine=_tree_binary(jnp.add),
        identity_fn=lambda *, example=None: jnp.zeros((depth, width), jnp.int32),
        lift=lift,
    )


def cms_query(sketch: jnp.ndarray, item: jnp.ndarray) -> jnp.ndarray:
    depth, width = sketch.shape
    ests = jnp.stack([sketch[d, _uhash(item, d) % width] for d in range(depth)])
    return jnp.min(ests)


def cms_update_batch(sketch: jnp.ndarray, items: jnp.ndarray,
                     weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Vectorized in-mapper combine of a whole batch into the sketch."""
    depth, width = sketch.shape
    if weights is None:
        weights = jnp.ones_like(items, jnp.int32)
    for d in range(depth):
        sketch = sketch.at[d, _uhash(items, d) % width].add(weights)
    return sketch

# ---------------------------------------------------------------------------
# HyperLogLog (paper §3, [Flajolet et al. 2007])
# ---------------------------------------------------------------------------

def _rho(v: jnp.ndarray, bitwidth: int) -> jnp.ndarray:
    """Position (1-based) of the leftmost 1 bit within `bitwidth` bits; 0 -> bitwidth+1."""
    shifts = jnp.arange(bitwidth - 1, -1, -1, dtype=jnp.uint32)
    bits = (v[..., None] >> shifts) & jnp.uint32(1)
    first_one = jnp.argmax(bits, axis=-1)
    any_one = jnp.any(bits > 0, axis=-1)
    return jnp.where(any_one, first_one + 1, bitwidth + 1).astype(jnp.uint8)


def hyperloglog(precision: int = 8) -> Monoid:
    """HLL with 2^precision registers; monoid under elementwise max."""
    p = precision
    m_regs = 1 << p
    suffix_bits = 32 - p

    def lift(item):
        h = _uhash(item, 7)
        idx = (h >> suffix_bits).astype(jnp.int32)
        suffix = h & jnp.uint32((1 << suffix_bits) - 1)
        r = _rho(suffix, suffix_bits)
        regs = jnp.zeros((m_regs,), jnp.uint8)
        return regs.at[idx].max(r)

    def extract(regs):
        if p >= 7:
            alpha = 0.7213 / (1 + 1.079 / m_regs)
        else:
            alpha = {4: 0.673, 5: 0.697, 6: 0.709}.get(p, 0.7213 / (1 + 1.079 / m_regs))
        z = jnp.sum(jnp.exp2(-regs.astype(jnp.float32)))
        est = alpha * m_regs * m_regs / z
        # small-range (linear counting) correction
        zeros = jnp.sum(regs == 0)
        lc = m_regs * jnp.log(m_regs / jnp.maximum(zeros, 1).astype(jnp.float32))
        return jnp.where((est <= 2.5 * m_regs) & (zeros > 0), lc, est)

    return Monoid(
        name=f"hll(p={p})",
        combine=_tree_binary(jnp.maximum),
        identity_fn=lambda *, example=None: jnp.zeros((m_regs,), jnp.uint8),
        lift=lift,
        extract=extract,
    )


def hll_update_batch(regs: jnp.ndarray, items: jnp.ndarray,
                     valid_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Vectorized in-mapper combine of a batch of ids into the registers.

    ``valid_mask`` marks the items that count (ragged/padded batches);
    invalid items contribute rank 0 — a no-op under the register max.
    """
    p = int(math.log2(regs.shape[-1]))
    suffix_bits = 32 - p
    h = _uhash(items, 7)
    idx = (h >> suffix_bits).astype(jnp.int32)
    suffix = h & jnp.uint32((1 << suffix_bits) - 1)
    r = _rho(suffix, suffix_bits)
    if valid_mask is not None:
        r = jnp.where(jnp.asarray(valid_mask, jnp.bool_), r, jnp.uint8(0))
    return regs.at[idx].max(r)

# ---------------------------------------------------------------------------
# stripes — the paper's Algorithm 5 (associative arrays under elementwise sum)
# ---------------------------------------------------------------------------

# A "stripe" H_w is the dense count vector over the (bucketed) vocabulary for
# focus word w. Associative arrays under element-wise sum == `sum_` on the
# dense representation; the monoid *is* sum, the representation is the point.
stripes = sum_


def stripe_of_window(window: jnp.ndarray, vocab: int, center: int) -> jnp.ndarray:
    """Lift one context window into the stripe for its center word (Alg 5 map)."""
    neigh = jnp.delete(window, center, assume_unique_indices=True)
    return jnp.zeros((vocab,), jnp.int32).at[neigh].add(1)


def cooccurrence_stripes(tokens: jnp.ndarray, vocab: int, window: int) -> jnp.ndarray:
    """Full (vocab, vocab) co-occurrence via stripes, in-mapper combined.

    tokens: (n,) int ids. Counts pairs (w, u) with |pos(w)-pos(u)| <= window,
    u != w position. Reference implementation (the Pallas kernel in
    kernels/stripes.py accelerates this).
    """
    n = tokens.shape[0]
    mat = jnp.zeros((vocab, vocab), jnp.int32)
    for offset in range(1, window + 1):   # window is static and small
        left = tokens[: n - offset]
        right = tokens[offset:]
        mat = mat.at[left, right].add(1)
        mat = mat.at[right, left].add(1)
    return mat

# ---------------------------------------------------------------------------
# exponential time-decay monoids — windowed streaming analytics
# ---------------------------------------------------------------------------
# State = (value, anchor_time): `value` is the decayed aggregate AS OF
# `anchor_time` (the latest event time folded in).  combine re-anchors both
# sides to the later time and merges — associative because the decayed
# aggregate is sum_i/max_i of x_i * 2^-((t - t_i)/half_life) and re-scaling
# by a common exp factor commutes with + and max.  The identity anchors at
# t = -inf with value 0: its decay weight to ANY finite time is exactly 0,
# so it is a two-sided unit (unlike an identity anchored at t=0, which
# re-weights values with earlier timestamps — the red test in
# tests/test_windows.py pins that failure mode).


def _decay_weight(t_from: jnp.ndarray, t_to: jnp.ndarray,
                  lam: float) -> jnp.ndarray:
    """exp(-lam*(t_to - t_from)) with the convention weight(-inf -> t) = 0.

    The where() guard keeps the identity exact: exp(-inf - -inf) would be
    NaN in the untaken branch, but the literal 0.0 is selected instead.
    """
    t_from = jnp.asarray(t_from, jnp.float32)
    return jnp.where(jnp.isneginf(t_from), jnp.float32(0.0),
                     jnp.exp(-lam * (jnp.asarray(t_to, jnp.float32) - t_from)))


def _decay_combine(lam: float, op):
    def combine(a, b):
        (va, ta), (vb, tb) = a, b
        t = jnp.maximum(jnp.asarray(ta, jnp.float32),
                        jnp.asarray(tb, jnp.float32))
        return (op(va * _decay_weight(ta, t, lam),
                   vb * _decay_weight(tb, t, lam)), t)
    return combine


def _decay_identity(*, example=None):
    if example is None:
        return (jnp.zeros(()), jnp.full((), -jnp.inf))
    v, t = example
    return (jnp.zeros_like(v), jnp.full_like(jnp.asarray(t, jnp.float32),
                                             -jnp.inf))


def _decay_monoid(name: str, half_life: float, op, lift) -> Monoid:
    if half_life <= 0:
        raise ValueError(f"half_life must be positive, got {half_life}")
    lam = math.log(2.0) / float(half_life)
    return Monoid(
        name=f"{name}(hl={half_life:g})",
        combine=_decay_combine(lam, op),
        identity_fn=_decay_identity,
        lift=lift,
        extract=lambda s: s[0],     # aggregate as-of the anchor time s[1]
    )


def _decay_lift(vt):
    v, t = vt
    return (jnp.asarray(v, jnp.float32), jnp.asarray(t, jnp.float32))


def decayed_sum(half_life: float) -> Monoid:
    """Exponentially-decayed sum: fold (value, time) events; the state is
    the decayed total as of the newest event.  half_life in time units."""
    return _decay_monoid("decayed_sum", half_life, jnp.add, _decay_lift)


def decayed_count(half_life: float) -> Monoid:
    """Decayed event count: :func:`decayed_sum` with lift (t) -> (1, t) —
    a rate estimator (events per recent half-life window)."""
    return _decay_monoid(
        "decayed_count", half_life, jnp.add,
        lambda t: (jnp.ones((), jnp.float32), jnp.asarray(t, jnp.float32)))


def decayed_lru(half_life: float) -> Monoid:
    """Decayed-LRU score: max over accesses of the decayed access weight —
    the cache-eviction score (recency with smooth aging).  Access weights
    must be non-negative (0 is the identity value)."""
    return _decay_monoid(
        "decayed_lru", half_life, jnp.maximum,
        lambda vt: (jnp.maximum(jnp.asarray(vt[0], jnp.float32), 0.0),
                    jnp.asarray(vt[1], jnp.float32)))


def decayed_value(state, t, half_life: float) -> jnp.ndarray:
    """Re-anchor a decay-monoid state to query time ``t`` (extract-at-t)."""
    v, ts = state
    lam = math.log(2.0) / float(half_life)
    return v * _decay_weight(ts, t, lam)


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------

def product(**named: Monoid) -> Monoid:
    """Product monoid over a dict of monoids — one collective for many stats."""
    names = sorted(named)

    def combine(a, b):
        return {k: named[k].combine(a[k], b[k]) for k in names}

    def identity_fn(*, example=None):
        if example is None:
            return {k: named[k].identity() for k in names}
        return {k: named[k].identity_like(example[k]) for k in names}

    def lift(x):
        return {k: named[k].lift(x[k]) for k in names}

    def extract(m):
        return {k: named[k].extract(m[k]) for k in names}

    return Monoid(
        name="product(" + ",".join(f"{k}={named[k].name}" for k in names) + ")",
        combine=combine,
        identity_fn=identity_fn,
        lift=lift,
        extract=extract,
        commutative=all(named[k].commutative for k in names),
    )


def cache_stats(half_life: float) -> Monoid:
    """Per-node prefix-cache bookkeeping state, folded as ONE monoid.

    The radix prefix KV cache (``runtime/prefix_cache.py``) keys a stats
    table by trie-node id and updates it with a single planner-lowered keyed
    fold per engine step — hit counting (additive), resident-byte accounting
    (additive), and the :func:`decayed_lru` eviction score all ride in one
    :func:`product` value, so the cache's whole bookkeeping is one
    ``execute_fold`` call per step, the same shape as the engine's
    per-request metrics fold.
    """
    import dataclasses as _dc
    m = product(bytes=sum_, hits=sum_, score=decayed_lru(half_life))
    return _dc.replace(m, name=f"cache_stats(hl={half_life:g})")


REGISTRY: Dict[str, Monoid] = {
    "sum": sum_,
    "prod": prod,
    "max": max_,
    "min": min_,
    "mean": mean,
    "count": count,
    "welford": welford,
    "logsumexp": logsumexp,
    "attn_state": attn_state,
    "affine_scan": affine_scan,
    "bitwise_or": bitwise_or,
}

# ---------------------------------------------------------------------------
# law-sample registry — what makes the CI monoid-law step discovery-driven
# ---------------------------------------------------------------------------
# Every monoid in REGISTRY must come with a sample provider: a zero-arg
# callable returning a few representative *monoid values* (post-lift) that
# `monoid.check_laws` can combine.  tests/test_monoid_laws.py enumerates
# the registry and fails CI with a pointed message for any monoid that was
# registered without one, so a new monoid cannot ship law-unchecked.

_LAW_SAMPLES: Dict[str, object] = {}   # name -> Callable[[], List[Pytree]]


def register_monoid(m: Monoid, law_samples, *, replace: bool = False) -> Monoid:
    """Register ``m`` in :data:`REGISTRY` together with its law samples.

    ``law_samples`` is a zero-arg callable returning >= 3 monoid values
    (so associativity has three distinct operands).  Registering a name
    twice without ``replace=True`` is an error — silently shadowing a
    monoid is how laws stop being checked.
    """
    if m.name in REGISTRY and not replace:
        raise ValueError(f"monoid {m.name!r} is already registered")
    REGISTRY[m.name] = m
    _LAW_SAMPLES[m.name] = law_samples
    return m


def law_samples_for(name: str):
    """The registered sample provider for ``name`` (None when missing)."""
    return _LAW_SAMPLES.get(name)


def missing_law_samples() -> list:
    """Registered monoid names with no law samples — must stay empty."""
    return sorted(name for name in REGISTRY if name not in _LAW_SAMPLES)


def law_suite():
    """Yield ``(monoid, samples)`` for every registered monoid that has a
    sample provider; the discovery test asserts none are missing first."""
    for name in sorted(REGISTRY):
        fn = _LAW_SAMPLES.get(name)
        if fn is not None:
            yield REGISTRY[name], fn()


def _f32(seed, shape=(3,)):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _zoo_law_samples() -> Dict[str, object]:
    """Sample providers for the built-in zoo (values are post-lift states)."""
    return {
        "sum": lambda: [_f32(s) for s in (0, 1, 2)],
        "prod": lambda: [_f32(s) * 0.5 + 1.0 for s in (0, 1, 2)],
        "max": lambda: [_f32(s) for s in (3, 4, 5)],
        "min": lambda: [_f32(s) for s in (6, 7, 8)],
        "bitwise_or": lambda: [
            jnp.asarray(np.random.default_rng(s).integers(0, 255, 4),
                        np.uint8) for s in (0, 1, 2)],
        "mean": lambda: [(_f32(s), jnp.asarray(s + 1, jnp.int32))
                         for s in (0, 1, 2)],
        "count": lambda: [jnp.asarray(c, jnp.int32) for c in (1, 4, 9)],
        "welford": lambda: [
            (jnp.asarray(float(n)), _f32(n, ()), jnp.abs(_f32(n + 10, ())))
            for n in (1, 2, 3)],
        "logsumexp": lambda: [(_f32(s, ()), jnp.abs(_f32(s + 20, ())) + 0.1)
                              for s in (0, 1, 2)],
        "attn_state": lambda: [
            (_f32(s, ()), jnp.abs(_f32(s + 30, ())) + 0.1, _f32(s + 40, (4,)))
            for s in (0, 1, 2)],
        "affine_scan": lambda: [(_f32(s) * 0.5 + 1.0, _f32(s + 50))
                                for s in (0, 1, 2)],
    }


_LAW_SAMPLES.update(_zoo_law_samples())

# representative instances of the parametrized factories, so the discovery
# suite exercises their combine/identity too (the factories themselves are
# covered via these: the laws do not depend on the size parameters)
register_monoid(top_k(4), lambda: [
    top_k(4).lift((jnp.asarray(v, jnp.float32), jnp.asarray(i, jnp.int32)))
    for v, i in ((3.0, 7), (1.5, 2), (9.0, 5))])
register_monoid(bloom_filter(64, 2), lambda: [
    bloom_filter(64, 2).lift(jnp.asarray(x, jnp.int32)) for x in (3, 11, 42)])
register_monoid(count_min(2, 32), lambda: [
    count_min(2, 32).lift(jnp.asarray(x, jnp.int32)) for x in (3, 11, 42)])
register_monoid(hyperloglog(4), lambda: [
    hyperloglog(4).lift(jnp.asarray(x, jnp.int32)) for x in (3, 11, 42)])

# decay monoids (windowed streaming analytics): samples are post-lift
# (value, anchor_time) states with DISTINCT finite times — including a
# negative one, so a broken identity anchored at t=0 cannot slip through
# the law suite (it only fails on values older than its anchor)
register_monoid(decayed_sum(16.0), lambda: [
    (_f32(s, ()), jnp.asarray(t, jnp.float32))
    for s, t in ((0, -3.0), (1, 2.5), (2, 7.0))])
register_monoid(decayed_count(16.0), lambda: [
    (jnp.abs(_f32(s, ())) + 1.0, jnp.asarray(t, jnp.float32))
    for s, t in ((3, -1.0), (4, 4.0), (5, 9.5))])
register_monoid(decayed_lru(16.0), lambda: [
    (jnp.abs(_f32(s, ())), jnp.asarray(t, jnp.float32))
    for s, t in ((6, -2.0), (7, 3.0), (8, 8.0))])

# the prefix-cache stats product (PR 10): samples exercise the additive
# hit/byte columns together with the decayed-LRU score column, again with
# distinct finite anchor times including a negative one
register_monoid(cache_stats(32.0), lambda: [
    {"bytes": jnp.abs(_f32(s, ())) * 1e3,
     "hits": jnp.abs(_f32(s + 10, ())),
     "score": (jnp.abs(_f32(s + 20, ())), jnp.asarray(t, jnp.float32))}
    for s, t in ((11, -4.0), (12, 1.5), (13, 6.0))])
