"""Distributed monoid aggregation — the paper's principle at cluster scale.

The paper's observation is that once the intermediate value is a monoid, the
execution framework is free to re-bracket the reduction any way it likes:
per-record, per-block, per-device, per-pod. This module is that freedom made
executable on a TPU mesh:

* :func:`local_fold` — the combiner, run before any collective touches the
  wire (Hadoop: "combiner"; here: on-device fold).  Keyed folds live in
  :mod:`repro.core.plan` (`execute_fold`), the single lowering path.
* :func:`monoid_allreduce` — a monoid combine across a mesh axis, lowering to
  the cheapest collective the monoid admits (psum/pmax/pmin for the
  elementwise monoids, the flash-decoding rescale trick for ``attn_state``,
  and an all_gather + tree-fold fallback for arbitrary monoids).
* :func:`hierarchical_psum` / :func:`monoid_hierarchical_allreduce` — the
  rack-aware aggregation of §2: reduce-scatter inside the pod (fast ICI),
  all-reduce across pods (slow DCN) on the scattered shard, all-gather back
  inside the pod. Legal *only because* the value is a monoid.
* :func:`grad_accum_fold` — in-mapper combining over microbatches
  (Algorithm 4: an accumulator across inputs, emitted once).

Everything here is shard_map/jit friendly; nothing allocates outside XLA.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from .monoid import Monoid, Pytree, tree_fold, scan_fold

# ---------------------------------------------------------------------------
# local (on-device) folds — the combiner
# ---------------------------------------------------------------------------

def local_fold(m: Monoid, xs: Pytree, *, axis: int = 0, strategy: str = "tree") -> Pytree:
    """Fold stacked monoid values on-device before any communication.

    strategy='tree' — log-depth reduction (Algorithm 3's combiner over
    materialized map output); strategy='scan' — in-mapper combining
    (Algorithm 4, O(1) live values).
    """
    if strategy == "tree":
        return tree_fold(m, xs, axis=axis)
    if strategy == "scan":
        return scan_fold(m, xs, axis=axis)
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# cross-device combine — the shuffle, minimized
# ---------------------------------------------------------------------------

# matched against Monoid.name: monoids.stripes / monoids.grad_sum are
# aliases of sum_ (name 'sum'), so they need no entries of their own
_PSUM_LIKE = {"sum", "count"}
_PMAX_LIKE = {"max", "bitwise_or"}   # uint OR == max per bit-plane is NOT true;
# bitwise_or gets its own branch below.
_PMIN_LIKE = {"min"}


def monoid_allreduce(m: Monoid, x: Pytree, axis_name: Any) -> Pytree:
    """Combine monoid values across a named mesh axis (inside shard_map).

    Picks the cheapest legal collective:
      * additive monoids           -> one psum
      * max / min                  -> pmax / pmin
      * mean (sum, count)          -> one psum over the tuple
      * welford                    -> psum on (n, n*mean, M2-corrected) — see note
      * logsumexp / attn_state     -> pmax(m) then psum of rescaled terms
                                      (the distributed flash-decoding merge)
      * anything else              -> all_gather + on-device tree fold
    """
    name = m.name
    if name in _PSUM_LIKE or name == "mean":
        return jax.lax.psum(x, axis_name)
    if name == "max":
        return jax.lax.pmax(x, axis_name)
    if name in _PMIN_LIKE:
        return jax.lax.pmin(x, axis_name)
    if name == "bitwise_or":
        # OR of uint8 0/1 bitmaps == max; general uintN OR via pmax on bit-planes
        # is wasteful, so for sketches we keep 0/1 bitmaps and use pmax.
        return jax.lax.pmax(x, axis_name)
    if name == "logsumexp":
        mx, l = x
        g = jax.lax.pmax(mx, axis_name)
        scale = jnp.where(jnp.isneginf(mx), 0.0, jnp.exp(mx - g))
        return (g, jax.lax.psum(l * scale, axis_name))
    if name == "attn_state":
        mx, l, o = x
        g = jax.lax.pmax(mx, axis_name)
        scale = jnp.where(jnp.isneginf(mx), 0.0, jnp.exp(mx - g))
        l = jax.lax.psum(l * scale, axis_name)
        o = jax.lax.psum(o * scale[..., None], axis_name)
        return (g, l, o)
    if name.startswith("hll"):
        return jax.lax.pmax(x, axis_name)
    if name.startswith("cms"):
        return jax.lax.psum(x, axis_name)
    # generic fallback: gather everyone's value, fold on device.
    gathered = jax.tree_util.tree_map(
        lambda v: jax.lax.all_gather(v, axis_name, axis=0), x)
    return tree_fold(m, gathered, axis=0)


def monoid_reduce_scatter(m: Monoid, x: Pytree, axis_name: Any) -> Pytree:
    """Reduce-scatter a (S, ...) keyed monoid value: device i ends up owning
    the combined partials for key-shard i. Generic monoids use all_to_all +
    local fold (the MapReduce shuffle); additive monoids use psum_scatter.

    Leading leaf axis S must be divisible by the axis size.
    """
    axis_size = jax.lax.axis_size(axis_name)
    if m.name in _PSUM_LIKE or m.name == "mean" or m.name.startswith("cms"):
        return jax.tree_util.tree_map(
            lambda v: jax.lax.psum_scatter(v, axis_name, scatter_dimension=0,
                                           tiled=True), x)

    def shuffle(v):
        s = v.shape[0]
        assert s % axis_size == 0, f"key axis {s} not divisible by {axis_size}"
        v = v.reshape((axis_size, s // axis_size) + v.shape[1:])
        # send key-shard j to device j; receive one shard per source device
        return jax.lax.all_to_all(v, axis_name, split_axis=0, concat_axis=0,
                                  tiled=False)

    shuffled = jax.tree_util.tree_map(shuffle, x)      # (axis_size, S/axis, ...)
    return tree_fold(m, shuffled, axis=0)              # fold over sources


# ---------------------------------------------------------------------------
# hierarchical aggregation — reduce-scatter(ICI) -> all-reduce(DCN) -> all-gather(ICI)
# ---------------------------------------------------------------------------

def _pad_to(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    rem = (-n) % mult
    if rem:
        flat = jnp.concatenate([flat, jnp.zeros((rem,), flat.dtype)])
    return flat, n


def hierarchical_psum(tree: Pytree, *, ici_axis: Any, dcn_axis: Any = None) -> Pytree:
    """Sum a pytree across ici_axis (and optionally dcn_axis) hierarchically.

    Per leaf: flatten -> psum_scatter over the fast intra-pod axis (each
    device now holds 1/|ici| of the summed leaf) -> psum the small shard over
    the slow cross-pod axis -> all_gather back over the fast axis.

    DCN traffic per leaf is bytes/|ici| instead of the full leaf — this is the
    paper's rack-aware combiner tree, and it is legal purely by associativity
    + commutativity of +.
    """
    ici = jax.lax.axis_size(ici_axis)

    def per_leaf(x):
        flat, n = _pad_to(x, ici)
        shard = jax.lax.psum_scatter(flat, ici_axis, scatter_dimension=0, tiled=True)
        if dcn_axis is not None:
            shard = jax.lax.psum(shard, dcn_axis)
        full = jax.lax.all_gather(shard, ici_axis, axis=0, tiled=True)
        return full[:n].reshape(x.shape)

    return jax.tree_util.tree_map(per_leaf, tree)


def monoid_hierarchical_allreduce(m: Monoid, x: Pytree, axes: Sequence[Any]) -> Pytree:
    """Combine across several mesh axes, one axis at a time (fast axes first).

    Axis-by-axis reduction is a re-bracketing of the global combine — legal by
    associativity; the device order along each gathered axis is preserved, so
    non-commutative monoids are combined in mesh-lexicographic order.
    """
    for ax in axes:
        x = monoid_allreduce(m, x, ax)
    return x


# ---------------------------------------------------------------------------
# gradient accumulation — in-mapper combining over microbatches
# ---------------------------------------------------------------------------

def grad_accum_fold(loss_and_grad_fn: Callable[[Pytree, Pytree], Tuple[Pytree, Pytree]],
                    params: Pytree, microbatches: Pytree) -> Tuple[Pytree, Pytree]:
    """Fold gradients over a leading microbatch axis without materializing them.

    ``loss_and_grad_fn(params, microbatch) -> (metrics_monoid_value, grads)``.
    Both metrics and grads are folded with the Sum monoid in a lax.scan carry
    — the paper's Algorithm 4 with the weight-vector monoid of §3 — via the
    planner's in-mapper scan tier (:func:`repro.core.plan.execute_fold`).

    Returns (metrics_accum, grads_sum). Callers divide by the number of
    microbatches (an `extract`) if they want the mean.
    """
    from . import monoids          # local: monoids is a sibling, not a dep
    from .plan import execute_fold

    return execute_fold(monoids.sum_, microbatches, layout="scan",
                        map_fn=lambda mb: loss_and_grad_fn(params, mb))


# ---------------------------------------------------------------------------
# byte accounting (the paper's "intermediate KV pairs", TPU edition)
# ---------------------------------------------------------------------------

def tree_bytes(tree: Pytree) -> int:
    """Total bytes of all leaves (concrete arrays or ShapeDtypeStructs)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
    return int(total)


def allreduce_wire_bytes(nbytes: int, axis_size: int, *, algorithm: str = "ring") -> int:
    """Bytes each device puts on the wire for an all-reduce of nbytes."""
    if axis_size <= 1:
        return 0
    if algorithm == "ring":  # reduce-scatter + all-gather, 2(n-1)/n each way
        return int(2 * nbytes * (axis_size - 1) / axis_size)
    if algorithm == "gather":  # naive all-gather-everything
        return int(nbytes * (axis_size - 1))
    raise ValueError(algorithm)
