"""repro.core — the paper's contribution: monoids as the aggregation layer.

Public API:
  Monoid, check_laws, tree_fold, scan_fold           (monoid.py)
  the monoid zoo: sum_, mean, welford, attn_state,
    affine_scan, bloom_filter, count_min, hyperloglog (monoids.py)
  local_fold, monoid_allreduce,
    hierarchical_psum, grad_accum_fold               (aggregation.py)
  execute_fold, plan_fold, Plan, segment_fold        (plan.py — the unified
    execution planner: ONE lowering path to Pallas / segment-ops / mesh
    collectives for every fold)
  Calibration, default_calibration, load_calibration,
    save_calibration, use_calibration                (calibration.py — the
    measured time/byte cost model layout='auto' argmins over)
  MapReduceJob, average_by_key_job, ShuffleStats     (mapreduce.py)
"""
from .monoid import (KernelLowering, Monoid, MonoidTypeError, Pytree,
                     check_laws, check_structure, fold_map,
                     register_kernel_lowering, scan_fold, tree_fold)
from . import monoids
from .monoids import REGISTRY, product
from .aggregation import (grad_accum_fold, hierarchical_psum, local_fold,
                          monoid_allreduce, monoid_hierarchical_allreduce,
                          monoid_reduce_scatter, tree_bytes)
from .calibration import (Calibration, TierCoeff, calibration_path,
                          default_calibration, get_calibration,
                          load_calibration, save_calibration,
                          set_calibration, use_calibration)
from .plan import (Plan, TierPlan, collective_algorithm, execute_fold,
                   plan_fold, segment_fold)
from .mapreduce import (MapReduceJob, ShuffleStats, STRATEGIES,
                        algorithm2_combiner, average_by_key_job,
                        cooccurrence_stripes_job, validate_combiner,
                        word_count_job)
